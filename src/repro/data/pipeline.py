"""Plan-first pipeline API: ``plan(spec) -> Schedule``, ``execute(spec, schedule)``.

Every loading strategy compiles offline to the same
:class:`~repro.core.plan.Schedule` IR and one runtime replays it
(:class:`~repro.data.loaders.ScheduleExecutor`), so the public API splits
along exactly that seam:

    spec = LoaderSpec(
        loader="solar", backend="hdf5", path="/data/ptycho.h5",
        num_nodes=8, local_batch=32, num_epochs=6, buffer_size=1024,
        collect_data=True, prefetch_depth=2, num_workers=8,
    )
    schedule = plan(spec)                 # offline: compile (or load) the plan
    pipeline = execute(spec, schedule)    # runtime: replay it against the store
    for step_batch in pipeline:
        ...

``build_pipeline(spec)`` is their composition — the one-call form every
benchmark and the trainer use.  The plan side is where the amortization
lives: ``spec.plan_cache`` memoizes schedules on disk keyed by the
planner's config hash (:class:`~repro.core.planners.PlanCache`),
``spec.plan_path`` pins one explicit artifact (loaded when present, built
and saved when not), and a standalone ``plan(spec, num_samples=...)`` can
precompute artifacts with no dataset in sight (``repro.launch.train plan``).

``execute`` refuses schedules whose geometry or recorded ``config_hash``
contradicts the spec — replaying a plan built for a different run fails
loudly instead of training the wrong samples.

When the spec names a ``path``, the backend is opened (or, for
:func:`build_store`, created) through the registry in
:mod:`repro.data.backends`; a pre-opened ``store`` short-circuits that and
is used as-is (``path`` and ``store`` are mutually exclusive on the spec).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any

from repro.core.costmodel import PeerCostModel, PFSCostModel
from repro.core.plan import Schedule
from repro.core.planners import PLANNERS, PlanCache, Planner, SolarPlanner
from repro.core.scheduler import SolarConfig
from repro.data.backends.base import backend_names, create_store, open_store
from repro.stream.windows import STREAM_STRATEGY, StreamSpec, WindowPlanner

__all__ = [
    "LoaderSpec",
    "StreamSpec",
    "plan",
    "execute",
    "build_pipeline",
    "build_store",
    "make_planner",
]


@dataclasses.dataclass
class LoaderSpec:
    """Everything needed to stand up one data pipeline, in one place.

    The spec is plain data: cheap to construct, comparable, and
    ``dataclasses.replace``-able (see :meth:`replace`), so sweeps over
    loaders/backends/depths are one-liners.
    """

    #: loader strategy: ``naive`` | ``lru`` | ``nopfs`` | ``deepio`` | ``solar``.
    loader: str = "solar"
    #: storage backend name (see :func:`repro.data.backends.backend_names`).
    backend: str = "binary"
    #: dataset path, opened through the backend registry ...
    path: str | None = None
    #: ... or a pre-opened store (any :class:`StorageBackend`), used as-is.
    #: Exactly one of ``path``/``store`` may be set.
    store: Any = None
    num_nodes: int = 1
    local_batch: int = 32
    num_epochs: int = 1
    buffer_size: int = 1024
    seed: int = 0
    #: materialize sample arrays (False = counting/accounting only).
    collect_data: bool = False
    #: async read-ahead in steps; 0 = fully synchronous iteration.
    prefetch_depth: int = 0
    #: I/O threads for schedule-driven parallel chunk reads.
    num_workers: int = 4
    #: plan + execute the peer-fetch tier (solar loader only, DESIGN.md §6):
    #: capacity-spilled misses are served from sibling node buffers instead
    #: of the PFS when the cost model prefers it.
    peer_fetch: bool = False
    #: peer-vs-PFS pricing override; derived from the store when None.
    peer_cost: PeerCostModel | None = None
    #: how planned peer fetches move: ``"shared"`` (in-process buffer
    #: mirrors — the loader zoo and the benchmarks) or ``"socket"`` (real
    #: per-node buffer servers over TCP; such specs are executed by
    #: :func:`repro.runtime.run_distributed`, which supplies the live
    #: :class:`~repro.data.peer.SocketTransport` per rank).
    transport: str = "shared"
    #: scheduler overrides (solar loader only); derived from the fields
    #: above when None.
    solar: SolarConfig | None = None
    #: PFS pricing override for modeled time; derived from the store when None.
    cost_model: PFSCostModel | None = None
    #: backend open/create options (e.g. ``simulated_latency_s``,
    #: ``rdcc_nbytes``/``align_chunks`` for hdf5, ``num_shards`` for sharded).
    backend_options: dict = dataclasses.field(default_factory=dict)
    #: directory memoizing compiled schedules by config hash (DESIGN.md §7);
    #: ``plan(spec)`` loads on hit, builds + stores on miss.
    plan_cache: str | None = None
    #: explicit plan-artifact path: loaded (and hash-verified) when present,
    #: built and saved there when not.  Mutually exclusive with ``plan_cache``.
    plan_path: str | None = None
    #: streaming-ingestion knobs (DESIGN.md §10); required iff
    #: ``loader="stream"``.  Stream specs compile plans incrementally per
    #: sealed window (:mod:`repro.stream`), so offline ``plan()`` and the
    #: plan cache/artifact paths do not apply to them.
    stream: StreamSpec | None = None

    def replace(self, **changes) -> "LoaderSpec":
        return dataclasses.replace(self, **changes)

    def validate(self) -> "LoaderSpec":
        """Raise one ``ValueError`` naming every inconsistency in the spec."""
        errs = []
        if self.loader not in PLANNERS and self.loader != STREAM_STRATEGY:
            errs.append(
                f"unknown loader {self.loader!r}; have "
                f"{sorted(PLANNERS) + [STREAM_STRATEGY]}"
            )
        if self.loader == STREAM_STRATEGY and self.stream is None:
            errs.append(
                "loader='stream' needs stream=StreamSpec(...) on the spec"
            )
        if self.stream is not None:
            if self.loader != STREAM_STRATEGY:
                errs.append(
                    f"stream=StreamSpec(...) requires loader='stream', "
                    f"got loader={self.loader!r}"
                )
            errs.extend(self.stream.validate())
            if self.plan_cache is not None or self.plan_path is not None:
                errs.append(
                    "streaming specs compile plans incrementally per sealed "
                    "window — 'plan_cache'/'plan_path' do not apply"
                )
        if self.store is None:
            if self.path is None:
                errs.append("one of 'path' or 'store' is required")
            if self.backend not in backend_names():
                errs.append(
                    f"unknown backend {self.backend!r}; have {backend_names()}"
                )
        elif self.path is not None:
            errs.append(
                "'path' and 'store' are mutually exclusive — pass the opened "
                "store or the path, not both"
            )
        for name in ("num_nodes", "local_batch", "num_epochs", "buffer_size"):
            if int(getattr(self, name)) <= 0:
                errs.append(f"{name} must be positive, got {getattr(self, name)}")
        if int(self.seed) < 0:
            errs.append(f"seed must be >= 0, got {self.seed}")
        if int(self.prefetch_depth) < 0:
            errs.append(f"prefetch_depth must be >= 0, got {self.prefetch_depth}")
        if int(self.num_workers) <= 0:
            errs.append(f"num_workers must be positive, got {self.num_workers}")
        if self.transport not in ("shared", "socket"):
            errs.append(
                f"unknown transport {self.transport!r}; have 'shared' "
                "(in-process mirrors) and 'socket' (per-node buffer servers)"
            )
        if self.plan_cache is not None and self.plan_path is not None:
            errs.append(
                "'plan_cache' and 'plan_path' are mutually exclusive — a "
                "cache directory or one pinned artifact, not both"
            )
        if self.solar is not None:
            if self.loader != "solar":
                errs.append("'solar' scheduler config requires loader='solar'")
            else:
                for spec_f, cfg_f in (
                    ("num_nodes", "num_nodes"),
                    ("local_batch", "local_batch"),
                    ("buffer_size", "buffer_size"),
                ):
                    if getattr(self.solar, cfg_f) != getattr(self, spec_f):
                        errs.append(
                            f"solar config {cfg_f}={getattr(self.solar, cfg_f)} "
                            f"contradicts spec {spec_f}={getattr(self, spec_f)}"
                        )
                if self.peer_fetch and not self.solar.enable_peer:
                    errs.append(
                        "peer_fetch=True contradicts solar config with "
                        "enable_peer=False"
                    )
                if (
                    self.peer_cost is not None
                    and self.solar.peer_cost is not None
                    and self.solar.peer_cost != self.peer_cost
                ):
                    errs.append(
                        "peer_cost set on both the spec and the solar config"
                    )
        if self.peer_fetch and self.loader != "solar":
            errs.append("peer_fetch requires loader='solar'")
        if self.peer_cost is not None and not (
            self.peer_fetch or (self.solar is not None and self.solar.enable_peer)
        ):
            errs.append("peer_cost is set but the peer-fetch tier is disabled")
        if errs:
            raise ValueError("invalid LoaderSpec: " + "; ".join(errs))
        return self


def build_store(spec: LoaderSpec, *, create: bool = False, **create_options):
    """Resolve the spec's store: pre-opened > open(path) > create(path).

    With ``create=True`` the dataset is created at ``spec.path`` through the
    backend registry when it does not exist yet (``create_options`` are
    forwarded, e.g. ``dataset=DatasetSpec(...), fill="random"``).  A key
    appearing in both ``create_options`` and ``spec.backend_options`` is a
    caller ambiguity and is rejected by name.
    """
    if spec.store is not None:
        return spec.store
    from repro.data.backends.base import get_backend

    cls = get_backend(spec.backend)
    if create and not cls.exists(spec.path):
        dataset = create_options.pop("dataset", None)
        if "spec" in create_options or "spec" in spec.backend_options:
            raise ValueError(
                "pass the dataset geometry as build_store(..., dataset=...), "
                "not as a 'spec' option — it collides with create_store's "
                "own parameter"
            )
        dup = sorted(set(create_options) & set(spec.backend_options))
        if dup:
            raise ValueError(
                "store options passed both directly to build_store and via "
                f"spec.backend_options: {dup} — set each option in one place"
            )
        return create_store(
            spec.path, spec.backend, spec=dataset,
            **create_options, **spec.backend_options,
        )
    return open_store(spec.path, spec.backend, **spec.backend_options)


def _resolve_store(spec: LoaderSpec, store) -> LoaderSpec:
    """Fold an explicitly passed (pre-opened) store into the spec.

    The ``store=`` keyword on :func:`plan`/:func:`execute`/
    :func:`build_pipeline` means "this is the opened store for this spec" —
    it replaces the spec's ``path`` resolution rather than silently racing
    it.  Passing a store that differs from one already on the spec is an
    error.
    """
    if store is None:
        return spec
    if spec.store is not None and spec.store is not store:
        raise ValueError(
            "conflicting stores: the spec carries one store and a different "
            "one was passed as the store= argument"
        )
    return spec.replace(store=store, path=None)


def _peer_needs_sample_bytes(spec: LoaderSpec) -> bool:
    """True when planning would have to derive a PeerCostModel from the
    store geometry (peer tier on, no explicit cost model anywhere)."""
    if spec.loader != "solar":
        return False
    peer_on = spec.peer_fetch or (
        spec.solar is not None and spec.solar.enable_peer
    )
    has_cost = spec.peer_cost is not None or (
        spec.solar is not None and spec.solar.peer_cost is not None
    )
    return peer_on and not has_cost


def make_planner(spec: LoaderSpec, *, sample_bytes: int | None = None) -> Planner:
    """Resolve the spec's strategy into a configured :class:`Planner`.

    ``sample_bytes`` (the store geometry) is needed only to derive a
    default :class:`PeerCostModel` when the peer tier is enabled without an
    explicit one — planning is otherwise dataset-content-free.
    """
    if spec.loader == STREAM_STRATEGY:
        raise ValueError(
            "stream specs have no offline planner: windows are compiled "
            "incrementally by repro.stream.WindowPlanner as manifests seal "
            "(drive them with repro.stream.run_stream / run_stream_distributed)"
        )
    if spec.loader == "solar":
        cfg = spec.solar
        if cfg is None:
            cfg = SolarConfig(
                num_nodes=spec.num_nodes,
                local_batch=spec.local_batch,
                buffer_size=spec.buffer_size,
                seed=spec.seed,
                enable_peer=spec.peer_fetch,
                peer_cost=spec.peer_cost,
            )
        elif spec.peer_cost is not None and cfg.peer_cost is None:
            cfg = dataclasses.replace(cfg, peer_cost=spec.peer_cost)
        if cfg.enable_peer and cfg.peer_cost is None:
            # Price the peer-vs-PFS decision with the store's real sample
            # size and the spec's PFS model.
            if sample_bytes is None:
                raise ValueError(
                    "planning the peer tier needs the store geometry "
                    "(sample_bytes) or an explicit peer_cost"
                )
            pfs = spec.cost_model or PFSCostModel(sample_bytes=sample_bytes)
            cfg = dataclasses.replace(
                cfg, peer_cost=PeerCostModel(sample_bytes=sample_bytes, pfs=pfs)
            )
        return SolarPlanner(config=cfg, seed=spec.seed)
    return PLANNERS[spec.loader](
        num_nodes=spec.num_nodes,
        local_batch=spec.local_batch,
        buffer_size=spec.buffer_size,
        seed=spec.seed,
    )


def plan(
    spec: LoaderSpec,
    *,
    store=None,
    num_samples: int | None = None,
) -> Schedule:
    """Compile (or load) the spec's :class:`Schedule` — the offline half.

    Resolution order: a ``plan_path`` artifact when it exists (verified
    against the spec's config hash — a stale or foreign file fails loudly),
    then the ``plan_cache`` keyed by config hash, then a fresh compile
    (saved back to ``plan_path``/``plan_cache`` when configured).

    Planning needs only the dataset *geometry*: pass ``num_samples`` to plan
    with no store at all (e.g. precomputing artifacts on a login node);
    otherwise the store is opened just long enough to read its size.
    """
    spec = _resolve_store(spec, store)
    if num_samples is not None and spec.store is None and spec.path is None:
        # geometry-only planning (e.g. precompute on a login node): no
        # dataset is required, so satisfy the path-or-store rule formally.
        spec.replace(path="<geometry-only>").validate()
    else:
        spec.validate()
    # Read the geometry whenever a store is already open — an explicit
    # num_samples must not cost the peer tier its sample_bytes.  A bare
    # path is opened when num_samples is missing, or briefly when the peer
    # tier needs sample_bytes anyway; pure geometry-only planning (neither
    # path nor store) stays dataset-free.
    sample_bytes = None
    if spec.store is not None:
        if num_samples is None:
            num_samples = spec.store.num_samples
        sample_bytes = spec.store.sample_bytes
    elif spec.path is not None and (
        num_samples is None or _peer_needs_sample_bytes(spec)
    ):
        st = build_store(spec)
        if num_samples is None:
            num_samples = st.num_samples
        sample_bytes = st.sample_bytes
        st.close()
    planner = make_planner(spec, sample_bytes=sample_bytes)
    key = planner.cache_key(num_samples, spec.num_epochs)
    if spec.plan_path is not None:
        if os.path.exists(spec.plan_path):
            return Schedule.load(spec.plan_path, expect_hash=key)
        schedule = planner.plan(num_samples, spec.num_epochs)
        schedule.save(spec.plan_path)
        return schedule
    if spec.plan_cache is not None:
        schedule, _hit = PlanCache(spec.plan_cache).load_or_build(
            planner, num_samples, spec.num_epochs
        )
        return schedule
    return planner.plan(num_samples, spec.num_epochs)


def execute(spec: LoaderSpec, schedule: Schedule, *, store=None,
            peer_transport=None):
    """Stand up the runtime half: replay ``schedule`` against the spec's store.

    Returns a :class:`~repro.data.loaders.ScheduleExecutor`, wrapped in a
    :class:`~repro.data.prefetch.PrefetchExecutor` when
    ``spec.prefetch_depth > 0`` — either way the result iterates
    :class:`~repro.data.loaders.StepBatch` objects and proxies the
    executor's ``report``/``capacity``/``store`` attributes.  The opened
    store is reachable as ``pipeline.store``; closing it is the caller's job
    (executors never own their store — several pipelines may share one).

    ``peer_transport`` injects a live :class:`~repro.data.peer.PeerTransport`
    (a rank's :class:`~repro.data.peer.SocketTransport` in multi-process
    runs); specs asking for ``transport="socket"`` *require* it — the
    sockets only exist inside :func:`repro.runtime.run_distributed`.

    The schedule must match the spec: strategy, geometry, epoch count, and —
    when the schedule records one — the planner's config hash.
    """
    from repro.data.loaders import ScheduleExecutor

    spec = _resolve_store(spec, store).validate()
    if spec.transport == "socket" and peer_transport is None:
        raise ValueError(
            "transport='socket' needs a live peer transport: multi-process "
            "runs are stood up by repro.runtime.run_distributed (which "
            "wires one SocketTransport per rank); use transport='shared' "
            "for in-process execution"
        )
    opened_here = spec.store is None
    st = spec.store if spec.store is not None else build_store(spec)
    try:
        solar_config = None
        serve_peers = None
        if spec.loader == STREAM_STRATEGY:
            # No offline planner: the schedule is the first window segment
            # (later ones arrive via executor.extend()); provenance is the
            # WindowPlanner's config hash instead of a planner cache key.
            _check_stream_schedule(spec, schedule)
            serve_peers = spec.stream.peer_fetch or peer_transport is not None
        else:
            planner = make_planner(spec, sample_bytes=st.sample_bytes)
            _check_schedule(spec, schedule, planner, st.num_samples)
            solar_config = (
                planner.config if isinstance(planner, SolarPlanner) else None
            )
        executor = ScheduleExecutor(
            st,
            schedule,
            collect_data=spec.collect_data,
            cost_model=spec.cost_model,
            solar_config=solar_config,
            peer_transport=peer_transport,
            serve_peers=serve_peers,
        )
    except BaseException:
        if opened_here:  # never leak a handle the caller cannot reach
            st.close()
        raise
    if spec.prefetch_depth:
        from repro.data.prefetch import PrefetchExecutor

        return PrefetchExecutor(
            executor, depth=spec.prefetch_depth, num_workers=spec.num_workers
        )
    return executor


def _check_stream_schedule(spec: LoaderSpec, schedule: Schedule) -> None:
    errs = []
    if schedule.strategy != STREAM_STRATEGY:
        errs.append(
            f"schedule was planned by {schedule.strategy!r}, stream specs "
            f"replay {STREAM_STRATEGY!r} segments"
        )
    for field in ("num_nodes", "local_batch", "buffer_size"):
        if getattr(schedule, field) != getattr(spec, field):
            errs.append(
                f"schedule {field}={getattr(schedule, field)} contradicts "
                f"spec {field}={getattr(spec, field)}"
            )
    if schedule.config_hash:
        key = WindowPlanner.for_spec(spec).config_hash()
        if schedule.config_hash != key:
            errs.append(
                f"window config hash {schedule.config_hash} != the spec's "
                f"{key} — the segment was planned under a different "
                "streaming config"
            )
    if errs:
        raise ValueError("schedule does not match spec: " + "; ".join(errs))


def _check_schedule(
    spec: LoaderSpec, schedule: Schedule, planner: Planner, num_samples: int
) -> None:
    errs = []
    if schedule.strategy != spec.loader:
        errs.append(
            f"schedule was planned by {schedule.strategy!r}, spec asks for "
            f"{spec.loader!r}"
        )
    for field in ("num_nodes", "local_batch", "buffer_size"):
        if getattr(schedule, field) != getattr(spec, field):
            errs.append(
                f"schedule {field}={getattr(schedule, field)} contradicts "
                f"spec {field}={getattr(spec, field)}"
            )
    if len(schedule.epochs) != spec.num_epochs:
        errs.append(
            f"schedule plans {len(schedule.epochs)} epochs, spec asks for "
            f"{spec.num_epochs}"
        )
    if schedule.config_hash:
        key = planner.cache_key(num_samples, spec.num_epochs)
        if schedule.config_hash != key:
            errs.append(
                f"schedule config hash {schedule.config_hash} != the spec's "
                f"planner hash {key} — it was built for a different config"
            )
    if errs:
        raise ValueError("schedule does not match spec: " + "; ".join(errs))


def build_pipeline(spec: LoaderSpec, *, store=None):
    """``execute(spec, plan(spec))`` sharing one opened store.

    The one-call form: compiles (or cache-loads) the plan, then stands up
    the executor against the same store.
    """
    spec = _resolve_store(spec, store).validate()
    opened_here = spec.store is None
    st = spec.store if spec.store is not None else build_store(spec)
    spec = _resolve_store(spec, st)
    try:
        return execute(spec, plan(spec))
    except BaseException:
        if opened_here:  # e.g. a stale plan_path artifact failing its checks
            st.close()
        raise
