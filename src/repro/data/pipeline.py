"""Builder-style loader pipeline: ``build_pipeline(LoaderSpec(...))``.

One validated place resolves everything a data pipeline needs — which
storage backend serves the bytes, which loader strategy walks the epochs,
the scheduler configuration, and how deep the async prefetch runs — instead
of the kwarg sprawl that ``make_loader`` had grown into:

    spec = LoaderSpec(
        loader="solar", backend="hdf5", path="/data/ptycho.h5",
        num_nodes=8, local_batch=32, num_epochs=6, buffer_size=1024,
        collect_data=True, prefetch_depth=2, num_workers=8,
    )
    pipeline = build_pipeline(spec)
    for step_batch in pipeline:
        ...

``build_pipeline`` returns the loader itself, or a
:class:`~repro.data.prefetch.PrefetchExecutor` wrapping it when
``prefetch_depth > 0`` — either way the result iterates
:class:`~repro.data.loaders.StepBatch` objects and proxies the loader's
``report``/``capacity``/``store`` attributes, so trainers and benchmarks
stay pipeline-shape-agnostic.  When the spec names a ``path``, the backend
is opened (or, for :func:`build_store`, created) through the registry in
:mod:`repro.data.backends`; a pre-opened ``store`` short-circuits that and
is used as-is.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.costmodel import PeerCostModel, PFSCostModel
from repro.core.scheduler import SolarConfig
from repro.data.backends.base import backend_names, create_store, open_store

__all__ = ["LoaderSpec", "build_pipeline", "build_store"]


@dataclasses.dataclass
class LoaderSpec:
    """Everything needed to stand up one data pipeline, in one place.

    The spec is plain data: cheap to construct, comparable, and
    ``dataclasses.replace``-able (see :meth:`replace`), so sweeps over
    loaders/backends/depths are one-liners.
    """

    #: loader strategy: ``naive`` | ``lru`` | ``nopfs`` | ``deepio`` | ``solar``.
    loader: str = "solar"
    #: storage backend name (see :func:`repro.data.backends.backend_names`).
    backend: str = "binary"
    #: dataset path, opened through the backend registry ...
    path: str | None = None
    #: ... or a pre-opened store (any :class:`StorageBackend`), used as-is.
    store: Any = None
    num_nodes: int = 1
    local_batch: int = 32
    num_epochs: int = 1
    buffer_size: int = 1024
    seed: int = 0
    #: materialize sample arrays (False = counting/accounting only).
    collect_data: bool = False
    #: async read-ahead in steps; 0 = fully synchronous iteration.
    prefetch_depth: int = 0
    #: I/O threads for schedule-driven parallel chunk reads.
    num_workers: int = 4
    #: plan + execute the peer-fetch tier (solar loader only, DESIGN.md §6):
    #: capacity-spilled misses are served from sibling node buffers instead
    #: of the PFS when the cost model prefers it.
    peer_fetch: bool = False
    #: peer-vs-PFS pricing override; derived from the store when None.
    peer_cost: PeerCostModel | None = None
    #: scheduler overrides (solar loader only); derived from the fields
    #: above when None.
    solar: SolarConfig | None = None
    #: PFS pricing override for modeled time; derived from the store when None.
    cost_model: PFSCostModel | None = None
    #: backend open/create options (e.g. ``simulated_latency_s``,
    #: ``rdcc_nbytes``/``align_chunks`` for hdf5, ``num_shards`` for sharded).
    backend_options: dict = dataclasses.field(default_factory=dict)

    def replace(self, **changes) -> "LoaderSpec":
        return dataclasses.replace(self, **changes)

    def validate(self) -> "LoaderSpec":
        """Raise one ``ValueError`` naming every inconsistency in the spec."""
        from repro.data.loaders import LOADERS

        errs = []
        if self.loader not in LOADERS:
            errs.append(f"unknown loader {self.loader!r}; have {sorted(LOADERS)}")
        if self.store is None:
            if self.path is None:
                errs.append("one of 'path' or 'store' is required")
            if self.backend not in backend_names():
                errs.append(
                    f"unknown backend {self.backend!r}; have {backend_names()}"
                )
        for name in ("num_nodes", "local_batch", "num_epochs", "buffer_size"):
            if int(getattr(self, name)) <= 0:
                errs.append(f"{name} must be positive, got {getattr(self, name)}")
        if int(self.prefetch_depth) < 0:
            errs.append(f"prefetch_depth must be >= 0, got {self.prefetch_depth}")
        if int(self.num_workers) <= 0:
            errs.append(f"num_workers must be positive, got {self.num_workers}")
        if self.solar is not None:
            if self.loader != "solar":
                errs.append("'solar' scheduler config requires loader='solar'")
            else:
                for spec_f, cfg_f in (
                    ("num_nodes", "num_nodes"),
                    ("local_batch", "local_batch"),
                    ("buffer_size", "buffer_size"),
                ):
                    if getattr(self.solar, cfg_f) != getattr(self, spec_f):
                        errs.append(
                            f"solar config {cfg_f}={getattr(self.solar, cfg_f)} "
                            f"contradicts spec {spec_f}={getattr(self, spec_f)}"
                        )
                if self.peer_fetch and not self.solar.enable_peer:
                    errs.append(
                        "peer_fetch=True contradicts solar config with "
                        "enable_peer=False"
                    )
                if (
                    self.peer_cost is not None
                    and self.solar.peer_cost is not None
                    and self.solar.peer_cost != self.peer_cost
                ):
                    errs.append(
                        "peer_cost set on both the spec and the solar config"
                    )
        if self.peer_fetch and self.loader != "solar":
            errs.append("peer_fetch requires loader='solar'")
        if self.peer_cost is not None and not (
            self.peer_fetch or (self.solar is not None and self.solar.enable_peer)
        ):
            errs.append("peer_cost is set but the peer-fetch tier is disabled")
        if errs:
            raise ValueError("invalid LoaderSpec: " + "; ".join(errs))
        return self


def build_store(spec: LoaderSpec, *, create: bool = False, **create_options):
    """Resolve the spec's store: pre-opened > open(path) > create(path).

    With ``create=True`` the dataset is created at ``spec.path`` through the
    backend registry when it does not exist yet (``create_options`` are
    forwarded, e.g. ``dataset=DatasetSpec(...), fill="random"``).
    """
    if spec.store is not None:
        return spec.store
    from repro.data.backends.base import get_backend

    cls = get_backend(spec.backend)
    if create and not cls.exists(spec.path):
        dataset = create_options.pop("dataset", None)
        return create_store(
            spec.path, spec.backend, spec=dataset,
            **create_options, **spec.backend_options,
        )
    return open_store(spec.path, spec.backend, **spec.backend_options)


def build_pipeline(spec: LoaderSpec, *, store=None):
    """Resolve a :class:`LoaderSpec` into a ready-to-iterate pipeline.

    Returns the loader, wrapped in a
    :class:`~repro.data.prefetch.PrefetchExecutor` when
    ``spec.prefetch_depth > 0``.  The opened store is reachable as
    ``pipeline.store``; closing it is the caller's job (loaders never own
    their store — several pipelines may share one).
    """
    from repro.data.loaders import LOADERS

    if store is not None:
        spec = spec.replace(store=store)
    spec.validate()
    store = build_store(spec)
    kwargs: dict = dict(
        cost_model=spec.cost_model, collect_data=spec.collect_data
    )
    if spec.loader == "solar":
        if spec.solar is not None:
            solar = spec.solar
            if spec.peer_cost is not None and solar.peer_cost is None:
                solar = dataclasses.replace(solar, peer_cost=spec.peer_cost)
            kwargs["solar_config"] = solar
        elif spec.peer_fetch:
            kwargs["solar_config"] = SolarConfig(
                num_nodes=spec.num_nodes,
                local_batch=spec.local_batch,
                buffer_size=spec.buffer_size,
                seed=spec.seed,
                enable_peer=True,
                peer_cost=spec.peer_cost,
            )
    loader = LOADERS[spec.loader](
        store,
        spec.num_nodes,
        spec.local_batch,
        spec.num_epochs,
        spec.buffer_size,
        spec.seed,
        **kwargs,
    )
    if spec.prefetch_depth:
        from repro.data.prefetch import PrefetchExecutor

        return PrefetchExecutor(
            loader, depth=spec.prefetch_depth, num_workers=spec.num_workers
        )
    return loader
