"""Asynchronous pipelined execution of the SOLAR schedule.

The offline :class:`~repro.core.plan.Schedule` makes every future access
known, so the runtime never has to guess what to read next — it only has to
*overlap* the reads with the consumer's compute.  :class:`PrefetchExecutor`
does exactly that:

  * **schedule mode** (any loader exposing ``plan_steps``/``execute_step``,
    i.e. :class:`~repro.data.loaders.ScheduleExecutor` — since the plan-first
    refactor that is *every* strategy, baselines included): a pipeline
    thread walks the plan ``depth`` steps ahead of the consumer and submits
    every node-step's coalesced :class:`~repro.core.plan.ChunkRead` batch to
    a thread pool, so PFS calls for *different* nodes and *future* steps are
    in flight concurrently; batches are then assembled strictly in plan
    order (buffer-mirror deltas are order-dependent) and handed to the
    consumer through a bounded queue.  A step's planned peer fetches
    (DESIGN.md §6) are gathered at assembly time — the only point where the
    buffer mirrors are in the start-of-step state the plan priced —
    overlapping the tail of that step's still-in-flight chunk reads.
  * **iterator mode** (plain iterables without a plan): the loader's own
    ``__iter__`` runs on the pipeline thread behind the same bounded queue —
    reads overlap the consumer's compute, but intra-step reads stay
    sequential because such loaders decide their accesses online.

The executor is storage-agnostic: chunk reads go through the wrapped
loader's ``store.read_ranges`` — any :class:`~repro.data.backends.base.
StorageBackend` whose open/close lifecycle tolerates concurrent in-flight
reads (the fd/handle-pool contract every built-in backend implements).
Build one declaratively by setting ``prefetch_depth`` on a
:class:`~repro.data.pipeline.LoaderSpec`.

The output queue is bounded (``depth`` entries, default 2 = double
buffering).  In schedule mode up to ``depth`` *assembled* batches queue for
the consumer while up to ``depth`` further steps of raw chunk reads are in
flight, so peak read-ahead is ~``2 * depth`` steps and host memory is
proportional to ``2 * depth * global_batch`` — size ``depth`` against host
RAM accordingly.  Shutdown is
cooperative: :meth:`close` (also triggered by abandoning the iterator or the
context manager) cancels the pipeline, drains the queue, joins the thread and
tears down the pool — no leaked threads, ever.  Every iteration owns its run
state (queue, cancel flag, threads), so finalizing a stale, abandoned
iterator can never cancel a newer one.
"""
from __future__ import annotations

import queue
import threading
from collections import deque
from concurrent.futures import ThreadPoolExecutor

from repro.obs import trace as obs_trace

__all__ = ["PrefetchExecutor", "WindowReadAhead"]

_SENTINEL = object()


class WindowReadAhead:
    """Chunk-read pipelining for the distributed rank loop (DESIGN.md §11).

    The epoch-window protocol removes the per-step barriers, so a rank is
    free to issue the coalesced :class:`~repro.core.plan.ChunkRead` batches
    of *future* steps (up to ``prefetch_depth`` ahead, never past the
    window edge) while the current step assembles — the same overlap
    :class:`PrefetchExecutor` gives a single-process run, restated for a
    loop that interleaves several owned node-executors and must keep
    gather/execute on the rank thread (the buffer-server mutation order is
    the protocol).  Only the PFS reads move off-thread; they are pure.
    """

    def __init__(self, num_workers: int = 4):
        self._pool = ThreadPoolExecutor(
            max_workers=max(int(num_workers), 1), thread_name_prefix="solar-io"
        )

    def submit(self, store, sp) -> list:
        """Issue one step-plan's per-node chunk reads; returns futures."""
        return [
            self._pool.submit(
                store.read_ranges, [(c.start, c.stop) for c in npn.chunks]
            )
            for npn in sp.nodes
        ]

    @staticmethod
    def collect(futs) -> list | None:
        """Resolve a :meth:`submit` result into ``chunk_arrays`` (or None)."""
        return [f.result() for f in futs] if futs else None

    def close(self) -> None:
        self._pool.shutdown(wait=True, cancel_futures=True)

    def __enter__(self) -> "WindowReadAhead":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class _Failure:
    """Wraps a producer-side exception for re-raise on the consumer thread."""

    def __init__(self, exc: BaseException):
        self.exc = exc


class _Run:
    """State owned by one iteration of the executor."""

    def __init__(self, depth: int, num_workers: int | None):
        self.cancel = threading.Event()
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.pool = (
            ThreadPoolExecutor(
                max_workers=num_workers, thread_name_prefix="solar-io"
            )
            if num_workers
            else None
        )
        self.thread: threading.Thread | None = None


class PrefetchExecutor:
    """Schedule-driven asynchronous prefetcher over a loader.

    Iterating a ``PrefetchExecutor`` yields exactly the same
    :class:`~repro.data.loaders.StepBatch` sequence (and fills the same
    :class:`~repro.data.loaders.LoaderReport`) as iterating the wrapped
    loader synchronously — only the wall-clock schedule changes.
    """

    def __init__(self, loader, depth: int = 2, num_workers: int = 4,
                 mode: str = "auto"):
        if mode not in ("auto", "schedule", "iterator"):
            raise ValueError(f"unknown prefetch mode {mode!r}")
        if mode == "auto":
            mode = "schedule" if hasattr(loader, "plan_steps") else "iterator"
        if mode == "schedule" and not hasattr(loader, "plan_steps"):
            raise ValueError(f"{type(loader).__name__} has no plan to pipeline")
        self.loader = loader
        self.mode = mode
        self.depth = max(int(depth), 1)
        self.num_workers = max(int(num_workers), 1)
        self._run: _Run | None = None

    # -- loader proxy ---------------------------------------------------------

    def __getattr__(self, name):
        # Fall through to the wrapped loader (report, capacity, store, ...)
        # so the executor is a drop-in replacement in the trainer/benchmarks.
        if name == "loader":
            raise AttributeError(name)
        return getattr(self.loader, name)

    # -- lifecycle ------------------------------------------------------------

    def __enter__(self) -> "PrefetchExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # pragma: no cover - best effort
        try:
            self.close()
        except Exception:
            pass

    @staticmethod
    def _close_run(run: _Run | None) -> None:
        if run is None:
            return
        run.cancel.set()
        thread = run.thread
        while thread is not None and thread.is_alive():
            try:  # drain so a producer blocked on a full queue can exit
                while True:
                    run.q.get_nowait()
            except queue.Empty:
                pass
            thread.join(timeout=0.05)
        run.thread = None
        if run.pool is not None:
            run.pool.shutdown(wait=True)
            run.pool = None

    def close(self) -> None:
        """Cancel the active pipeline and join every thread it started."""
        run, self._run = self._run, None
        self._close_run(run)

    # -- iteration ------------------------------------------------------------

    def __iter__(self):
        self.close()  # stop any previous in-flight run
        run = _Run(
            self.depth,
            self.num_workers
            if self.mode == "schedule" and self.loader.collect_data
            else None,
        )
        run.thread = threading.Thread(
            target=self._produce, args=(run,), name="solar-pipeline", daemon=True
        )
        self._run = run
        run.thread.start()
        return self._consume(run)

    def _consume(self, run: _Run):
        tr = obs_trace.get()
        try:
            while True:
                t0 = tr.t()
                item = run.q.get()
                tr.rec(obs_trace.PREFETCH_QWAIT, t0)
                if item is _SENTINEL:
                    break
                if isinstance(item, _Failure):
                    raise item.exc
                yield item
        finally:
            # Tear down *this* run only; a newer __iter__ owns self._run now.
            if self._run is run:
                self._run = None
            self._close_run(run)

    # -- producer side --------------------------------------------------------

    @staticmethod
    def _put(run: _Run, item) -> bool:
        """Blocking put that aborts when the pipeline is cancelled."""
        while not run.cancel.is_set():
            try:
                run.q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _produce(self, run: _Run) -> None:
        try:
            if self.mode == "schedule":
                self._produce_schedule(run)
            else:
                for sb in self.loader:
                    if not self._put(run, sb):
                        return
        except BaseException as exc:  # surfaced on the consumer thread
            self._put(run, _Failure(exc))
        finally:
            if not self._put(run, _SENTINEL):
                try:  # consumer may already be gone; best effort
                    run.q.put_nowait(_SENTINEL)
                except queue.Full:
                    pass

    def _produce_schedule(self, run: _Run) -> None:
        ld = self.loader
        collect = ld.collect_data
        gather_peers = getattr(ld, "gather_peers", None)
        steps = iter(ld.plan_steps())
        steps_ready = getattr(ld, "stream_steps_ready", None)
        pulled = 0
        #: (EpochPlan, StepPlan, per-node futures) issued but not yet assembled.
        pending: deque = deque()
        exhausted = False
        while not run.cancel.is_set():
            while not exhausted and len(pending) < self.depth:
                if pending and steps_ready is not None:
                    avail = steps_ready()
                    if avail is not None and pulled >= avail:
                        # Streaming walk would block waiting for the next
                        # extend(): assemble what we hold instead of stalling
                        # the whole pipe at the window boundary.  With
                        # nothing pending we do block here — the consumer is
                        # necessarily ahead and free to extend.
                        break
                try:
                    ep, sp = next(steps)
                    pulled += 1
                except StopIteration:
                    exhausted = True
                    break
                futs = None
                if collect:
                    futs = [
                        run.pool.submit(
                            ld.store.read_ranges,
                            [(c.start, c.stop) for c in npn.chunks],
                        )
                        for npn in sp.nodes
                    ]
                pending.append((ep, sp, futs))
            if not pending:
                return
            ep, sp, futs = pending.popleft()
            # Peer fetches are legal exactly now — the previous step's deltas
            # are applied, this step's are not — and they overlap the tail of
            # this step's in-flight chunk reads.
            peer_arrays = gather_peers(sp) if gather_peers is not None else None
            chunk_arrays = [f.result() for f in futs] if futs else None
            if gather_peers is not None:
                sb = ld.execute_step(
                    ep, sp, chunk_arrays=chunk_arrays, peer_arrays=peer_arrays
                )
            else:
                sb = ld.execute_step(ep, sp, chunk_arrays=chunk_arrays)
            if not self._put(run, sb):
                break
        # Cancelled: wait out in-flight reads so pool shutdown is clean.
        for _, _, futs in pending:
            for f in futs or ():
                f.cancel()
