"""Flat-binary chunked sample store — the original "PFS + HDF5" stand-in.

A minimal HDF5-like chunked dataset: a JSON header + one flat binary file
holding ``num_samples`` fixed-shape samples contiguously.  What matters for
SOLAR is preserved exactly:

  * a *ranged* read of samples ``[start, stop)`` is a single seek + one
    sequential read (this is what makes aggregated chunk loading win), and
  * a scattered read of k samples costs one pread per consecutive run of
    ids (adjacent ids are coalesced into ranged reads).

Every read is a real ``pread`` against the filesystem; benchmarks additionally
price the same access trace under :class:`repro.core.costmodel.PFSCostModel`
to model a remote Lustre/GPFS where the per-call cost dominates.

:class:`ChunkStore` is one implementation of the
:class:`repro.data.backends.base.StorageBackend` protocol (registered as the
``binary`` backend via :class:`repro.data.backends.binary.BinaryBackend`);
the geometry, stats, and coalescing read paths live in
:class:`~repro.data.backends.base.BaseBackend` and are shared with the
``hdf5``/``memory``/``sharded`` layouts.

Concurrency: reads are safe from any number of threads.  Each in-flight read
checks a private file descriptor out of a pool (growing it on demand, so fd
count tracks *peak concurrency*, not thread count), preads, and returns it —
parallel chunk fetches from the prefetch executor never serialize behind a
lock; only the counter updates share a short critical section.
``simulated_latency_s`` injects a per-pread sleep to emulate remote-PFS call
latency in benchmarks (``time.sleep`` releases the GIL, so injected latency
overlaps across threads exactly like real PFS round-trips would).
"""
from __future__ import annotations

import json
import os
import queue
import threading

import numpy as np

from repro.data.backends.base import BaseBackend, synthetic_blocks

__all__ = ["ChunkStore", "create_synthetic_store", "write_binary_layout"]

_HEADER_SUFFIX = ".header.json"


def write_binary_layout(
    path: str,
    data: np.ndarray | None = None,
    *,
    num_samples: int | None = None,
    sample_shape: tuple[int, ...] | None = None,
    dtype=np.float32,
    fill: str = "zeros",
    seed: int = 0,
) -> None:
    """Write the flat-binary layout (header + data file) without opening a
    store — shared by :meth:`ChunkStore.create` and the ``binary``/``memory``
    backends' creation paths."""
    if data is not None:
        num_samples = data.shape[0]
        sample_shape = tuple(data.shape[1:])
        dtype = data.dtype
    assert num_samples is not None and sample_shape is not None
    hdr = {
        "num_samples": int(num_samples),
        "sample_shape": [int(x) for x in sample_shape],
        "dtype": np.dtype(dtype).str,
    }
    with open(path + _HEADER_SUFFIX, "w") as f:
        json.dump(hdr, f)
    if data is not None:
        data.tofile(path)
    else:
        with open(path, "wb") as f:
            for _, block in synthetic_blocks(
                num_samples, sample_shape, dtype, fill, seed
            ):
                block.tofile(f)


class ChunkStore(BaseBackend):
    """Fixed-shape sample array stored contiguously in one file."""

    backend_name = "binary"

    def __init__(self, path: str, simulated_latency_s: float = 0.0):
        with open(path + _HEADER_SUFFIX) as f:
            hdr = json.load(f)
        super().__init__(
            int(hdr["num_samples"]),
            tuple(hdr["sample_shape"]),
            np.dtype(hdr["dtype"]),
            path=path,
            simulated_latency_s=simulated_latency_s,
        )
        self._fd_pool: queue.SimpleQueue = queue.SimpleQueue()
        self._fds: list[int] = []       # every fd ever opened, for close()
        self._fd_lock = threading.Lock()
        self._release_fd(self._open_fd())  # fail on a bad path right here

    # -- construction --------------------------------------------------------

    @classmethod
    def create(
        cls,
        path: str,
        data: np.ndarray | None = None,
        *,
        num_samples: int | None = None,
        sample_shape: tuple[int, ...] | None = None,
        dtype=np.float32,
        fill: str = "zeros",
        seed: int = 0,
    ) -> "ChunkStore":
        write_binary_layout(
            path, data, num_samples=num_samples, sample_shape=sample_shape,
            dtype=dtype, fill=fill, seed=seed,
        )
        return cls(path)

    @classmethod
    def exists(cls, path: str) -> bool:
        return os.path.exists(path) and os.path.exists(path + _HEADER_SUFFIX)

    # -- fd pool --------------------------------------------------------------

    def _open_fd(self) -> int:
        with self._fd_lock:
            if self._closed:
                raise ValueError(f"store {self.path!r} is closed")
            fd = os.open(self.path, os.O_RDONLY)
            self._fds.append(fd)
        return fd

    def _acquire_fd(self) -> int:
        """Check a descriptor out for one read (grow the pool on demand)."""
        if self._closed:
            raise ValueError(f"store {self.path!r} is closed")
        try:
            return self._fd_pool.get_nowait()
        except queue.Empty:
            return self._open_fd()

    def _release_fd(self, fd: int) -> None:
        # close() only tears down *pooled* descriptors; one that was in
        # flight when close() ran is retired here instead of re-pooled, so a
        # concurrent reader never preads a descriptor closed under it.
        if self._closed:
            self._close_fd(fd)
        else:
            self._fd_pool.put(fd)

    def _close_fd(self, fd: int) -> None:
        with self._fd_lock:
            if fd in self._fds:
                self._fds.remove(fd)
            else:  # already retired by a racing close()
                return
        try:
            os.close(fd)
        except OSError:  # pragma: no cover
            pass

    # -- physical read + lifecycle --------------------------------------------

    def _read_span(self, start: int, stop: int) -> np.ndarray:
        nbytes = (stop - start) * self.sample_bytes
        fd = self._acquire_fd()
        try:
            buf = os.pread(fd, nbytes, start * self.sample_bytes)
        finally:
            self._release_fd(fd)
        arr = np.frombuffer(buf, dtype=self.dtype)
        return arr.reshape((stop - start,) + self.sample_shape)

    def close(self) -> None:
        with self._fd_lock:
            self._closed = True
        while True:  # drain + close idle descriptors; in-flight ones retire
            try:     # themselves in _release_fd once their pread finishes
                fd = self._fd_pool.get_nowait()
            except queue.Empty:
                break
            self._close_fd(fd)


def create_synthetic_store(
    path: str,
    num_samples: int,
    sample_shape: tuple[int, ...],
    dtype=np.float32,
    kind: str = "arange",
    seed: int = 0,
) -> ChunkStore:
    """Synthetic scientific dataset (diffraction frames / token sequences)."""
    return ChunkStore.create(
        path,
        num_samples=num_samples,
        sample_shape=sample_shape,
        dtype=dtype,
        fill=kind,
        seed=seed,
    )
