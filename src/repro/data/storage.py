"""Chunked sample store — the "PFS + HDF5" layer.

h5py is unavailable in this offline container, so we implement a minimal
HDF5-like chunked dataset: a JSON header + one flat binary file holding
``num_samples`` fixed-shape samples contiguously.  What matters for SOLAR is
preserved exactly:

  * a *ranged* read of samples ``[start, stop)`` is a single seek + one
    sequential read (this is what makes aggregated chunk loading win), and
  * a scattered read of k samples costs k seeks + k small reads.

Every read is a real ``pread`` against the filesystem; benchmarks additionally
price the same access trace under :class:`repro.core.costmodel.PFSCostModel`
to model a remote Lustre/GPFS where the per-call cost dominates.
"""
from __future__ import annotations

import json
import os
import threading

import numpy as np

__all__ = ["ChunkStore", "create_synthetic_store"]

_HEADER_SUFFIX = ".header.json"


class ChunkStore:
    """Fixed-shape sample array stored contiguously in one file."""

    def __init__(self, path: str):
        self.path = path
        with open(path + _HEADER_SUFFIX) as f:
            hdr = json.load(f)
        self.num_samples = int(hdr["num_samples"])
        self.sample_shape = tuple(hdr["sample_shape"])
        self.dtype = np.dtype(hdr["dtype"])
        self.sample_bytes = int(
            self.dtype.itemsize * int(np.prod(self.sample_shape, dtype=np.int64))
        )
        self._fd = os.open(path, os.O_RDONLY)
        self._lock = threading.Lock()
        #: access trace: list of (sample_offset, run_length) — consumed by the
        #: cost model and the access-pattern benchmark; cheap to record.
        self.trace: list[tuple[int, int]] = []
        self.bytes_read = 0
        self.read_calls = 0

    # -- construction --------------------------------------------------------

    @classmethod
    def create(
        cls,
        path: str,
        data: np.ndarray | None = None,
        *,
        num_samples: int | None = None,
        sample_shape: tuple[int, ...] | None = None,
        dtype=np.float32,
        fill: str = "zeros",
        seed: int = 0,
    ) -> "ChunkStore":
        if data is not None:
            num_samples = data.shape[0]
            sample_shape = tuple(data.shape[1:])
            dtype = data.dtype
        assert num_samples is not None and sample_shape is not None
        hdr = {
            "num_samples": int(num_samples),
            "sample_shape": [int(x) for x in sample_shape],
            "dtype": np.dtype(dtype).str,
        }
        with open(path + _HEADER_SUFFIX, "w") as f:
            json.dump(hdr, f)
        if data is not None:
            data.tofile(path)
        else:
            sample_elems = int(np.prod(sample_shape, dtype=np.int64))
            rng = np.random.Generator(np.random.PCG64(seed))
            with open(path, "wb") as f:
                block = 4096
                for start in range(0, num_samples, block):
                    n = min(block, num_samples - start)
                    if fill == "zeros":
                        arr = np.zeros((n, sample_elems), np.dtype(dtype))
                    elif fill == "random":
                        if np.issubdtype(np.dtype(dtype), np.integer):
                            arr = rng.integers(
                                0, 255, size=(n, sample_elems)
                            ).astype(dtype)
                        else:
                            arr = rng.standard_normal((n, sample_elems)).astype(dtype)
                    elif fill == "arange":
                        # sample i filled with value i — lets tests verify reads.
                        arr = np.broadcast_to(
                            np.arange(start, start + n, dtype=np.int64)[:, None],
                            (n, sample_elems),
                        ).astype(dtype)
                    else:
                        raise ValueError(f"unknown fill {fill!r}")
                    arr.tofile(f)
        return cls(path)

    # -- reads ----------------------------------------------------------------

    def read_range(self, start: int, stop: int) -> np.ndarray:
        """One ranged read: samples [start, stop) in a single pread."""
        if not 0 <= start < stop <= self.num_samples:
            raise IndexError((start, stop, self.num_samples))
        nbytes = (stop - start) * self.sample_bytes
        with self._lock:
            buf = os.pread(self._fd, nbytes, start * self.sample_bytes)
            self.trace.append((start, stop - start))
            self.bytes_read += nbytes
            self.read_calls += 1
        arr = np.frombuffer(buf, dtype=self.dtype)
        return arr.reshape((stop - start,) + self.sample_shape)

    def read_one(self, idx: int) -> np.ndarray:
        return self.read_range(idx, idx + 1)[0]

    def read_scattered(self, ids) -> np.ndarray:
        """k single-sample reads (the random-access baseline pattern)."""
        return np.stack([self.read_one(int(i)) for i in ids]) if len(ids) else (
            np.empty((0,) + self.sample_shape, self.dtype)
        )

    def reset_counters(self) -> None:
        self.trace.clear()
        self.bytes_read = 0
        self.read_calls = 0

    def close(self) -> None:
        if self._fd >= 0:
            os.close(self._fd)
            self._fd = -1

    def __del__(self):  # pragma: no cover - best effort
        try:
            self.close()
        except Exception:
            pass


def create_synthetic_store(
    path: str,
    num_samples: int,
    sample_shape: tuple[int, ...],
    dtype=np.float32,
    kind: str = "arange",
    seed: int = 0,
) -> ChunkStore:
    """Synthetic scientific dataset (diffraction frames / token sequences)."""
    return ChunkStore.create(
        path,
        num_samples=num_samples,
        sample_shape=sample_shape,
        dtype=dtype,
        fill=kind,
        seed=seed,
    )
