"""Chunked sample store — the "PFS + HDF5" layer.

h5py is unavailable in this offline container, so we implement a minimal
HDF5-like chunked dataset: a JSON header + one flat binary file holding
``num_samples`` fixed-shape samples contiguously.  What matters for SOLAR is
preserved exactly:

  * a *ranged* read of samples ``[start, stop)`` is a single seek + one
    sequential read (this is what makes aggregated chunk loading win), and
  * a scattered read of k samples costs one pread per consecutive run of
    ids (adjacent ids are coalesced into ranged reads).

Every read is a real ``pread`` against the filesystem; benchmarks additionally
price the same access trace under :class:`repro.core.costmodel.PFSCostModel`
to model a remote Lustre/GPFS where the per-call cost dominates.

Concurrency: reads are safe from any number of threads.  Each in-flight read
checks a private file descriptor out of a pool (growing it on demand, so fd
count tracks *peak concurrency*, not thread count), preads, and returns it —
parallel chunk fetches from the prefetch executor never serialize behind a
lock; only the counter updates share a short critical section.
``simulated_latency_s`` injects a per-pread sleep to emulate remote-PFS call
latency in benchmarks (``time.sleep`` releases the GIL, so injected latency
overlaps across threads exactly like real PFS round-trips would).
"""
from __future__ import annotations

import json
import os
import queue
import threading
import time

import numpy as np

__all__ = ["ChunkStore", "create_synthetic_store"]

_HEADER_SUFFIX = ".header.json"


class ChunkStore:
    """Fixed-shape sample array stored contiguously in one file."""

    def __init__(self, path: str, simulated_latency_s: float = 0.0):
        self.path = path
        with open(path + _HEADER_SUFFIX) as f:
            hdr = json.load(f)
        self.num_samples = int(hdr["num_samples"])
        self.sample_shape = tuple(hdr["sample_shape"])
        self.dtype = np.dtype(hdr["dtype"])
        self.sample_bytes = int(
            self.dtype.itemsize * int(np.prod(self.sample_shape, dtype=np.int64))
        )
        #: per-pread sleep emulating remote-PFS call latency (benchmarks only).
        self.simulated_latency_s = float(simulated_latency_s)
        self._fd_pool: queue.SimpleQueue = queue.SimpleQueue()
        self._fds: list[int] = []       # every fd ever opened, for close()
        self._fd_lock = threading.Lock()
        self._closed = False
        self._stats_lock = threading.Lock()
        #: access trace: list of (sample_offset, run_length) — consumed by the
        #: cost model and the access-pattern benchmark; cheap to record.
        self.trace: list[tuple[int, int]] = []
        self.bytes_read = 0
        self.read_calls = 0
        self._release_fd(self._open_fd())  # fail on a bad path right here

    # -- construction --------------------------------------------------------

    @classmethod
    def create(
        cls,
        path: str,
        data: np.ndarray | None = None,
        *,
        num_samples: int | None = None,
        sample_shape: tuple[int, ...] | None = None,
        dtype=np.float32,
        fill: str = "zeros",
        seed: int = 0,
    ) -> "ChunkStore":
        if data is not None:
            num_samples = data.shape[0]
            sample_shape = tuple(data.shape[1:])
            dtype = data.dtype
        assert num_samples is not None and sample_shape is not None
        hdr = {
            "num_samples": int(num_samples),
            "sample_shape": [int(x) for x in sample_shape],
            "dtype": np.dtype(dtype).str,
        }
        with open(path + _HEADER_SUFFIX, "w") as f:
            json.dump(hdr, f)
        if data is not None:
            data.tofile(path)
        else:
            sample_elems = int(np.prod(sample_shape, dtype=np.int64))
            rng = np.random.Generator(np.random.PCG64(seed))
            with open(path, "wb") as f:
                block = 4096
                for start in range(0, num_samples, block):
                    n = min(block, num_samples - start)
                    if fill == "zeros":
                        arr = np.zeros((n, sample_elems), np.dtype(dtype))
                    elif fill == "random":
                        if np.issubdtype(np.dtype(dtype), np.integer):
                            arr = rng.integers(
                                0, 255, size=(n, sample_elems)
                            ).astype(dtype)
                        else:
                            arr = rng.standard_normal((n, sample_elems)).astype(dtype)
                    elif fill == "arange":
                        # sample i filled with value i — lets tests verify reads.
                        arr = np.broadcast_to(
                            np.arange(start, start + n, dtype=np.int64)[:, None],
                            (n, sample_elems),
                        ).astype(dtype)
                    else:
                        raise ValueError(f"unknown fill {fill!r}")
                    arr.tofile(f)
        return cls(path)

    # -- fd pool --------------------------------------------------------------

    def _open_fd(self) -> int:
        with self._fd_lock:
            if self._closed:
                raise ValueError(f"store {self.path!r} is closed")
            fd = os.open(self.path, os.O_RDONLY)
            self._fds.append(fd)
        return fd

    def _acquire_fd(self) -> int:
        """Check a descriptor out for one read (grow the pool on demand)."""
        if self._closed:
            raise ValueError(f"store {self.path!r} is closed")
        try:
            return self._fd_pool.get_nowait()
        except queue.Empty:
            return self._open_fd()

    def _release_fd(self, fd: int) -> None:
        # close() only tears down *pooled* descriptors; one that was in
        # flight when close() ran is retired here instead of re-pooled, so a
        # concurrent reader never preads a descriptor closed under it.
        if self._closed:
            self._close_fd(fd)
        else:
            self._fd_pool.put(fd)

    def _close_fd(self, fd: int) -> None:
        with self._fd_lock:
            if fd in self._fds:
                self._fds.remove(fd)
            else:  # already retired by a racing close()
                return
        try:
            os.close(fd)
        except OSError:  # pragma: no cover
            pass

    # -- reads ----------------------------------------------------------------

    def read_range(self, start: int, stop: int) -> np.ndarray:
        """One ranged read: samples [start, stop) in a single pread."""
        if not 0 <= start < stop <= self.num_samples:
            raise IndexError((start, stop, self.num_samples))
        nbytes = (stop - start) * self.sample_bytes
        fd = self._acquire_fd()
        try:
            if self.simulated_latency_s > 0.0:
                time.sleep(self.simulated_latency_s)
            buf = os.pread(fd, nbytes, start * self.sample_bytes)
        finally:
            self._release_fd(fd)
        with self._stats_lock:
            self.trace.append((start, stop - start))
            self.bytes_read += nbytes
            self.read_calls += 1
        arr = np.frombuffer(buf, dtype=self.dtype)
        return arr.reshape((stop - start,) + self.sample_shape)

    def read_one(self, idx: int) -> np.ndarray:
        return self.read_range(idx, idx + 1)[0]

    def read_ranges(self, ranges) -> list[np.ndarray]:
        """Ranged reads with adjacency coalescing.

        ``ranges`` is a sequence of ``(start, stop)`` pairs.  Consecutive pairs
        whose spans touch (``prev_stop == next_start``) are merged into one
        pread and split back afterwards, so a run of adjacent
        :class:`~repro.core.plan.ChunkRead`\\ s costs a single PFS call.
        Returns one array per input range, in input order.
        """
        ranges = [(int(a), int(b)) for a, b in ranges]
        out: list[np.ndarray | None] = [None] * len(ranges)
        i = 0
        while i < len(ranges):
            j = i
            while j + 1 < len(ranges) and ranges[j + 1][0] == ranges[j][1]:
                j += 1
            lo, hi = ranges[i][0], ranges[j][1]
            arr = self.read_range(lo, hi)
            for k in range(i, j + 1):
                a, b = ranges[k]
                out[k] = arr[a - lo : b - lo]
            i = j + 1
        return out  # type: ignore[return-value]

    def read_scattered(self, ids) -> np.ndarray:
        """Scattered read of k samples, coalescing consecutive ids.

        Ids are sorted, runs of adjacent ids become single ranged preads, and
        rows come back in the caller's original order (duplicates allowed).
        """
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size == 0:
            return np.empty((0,) + self.sample_shape, self.dtype)
        order = np.argsort(ids, kind="stable")
        sids = ids[order]
        breaks = np.flatnonzero(np.diff(sids) > 1) + 1
        starts = np.concatenate([[0], breaks])
        ends = np.concatenate([breaks, [sids.size]])
        out = np.empty((ids.size,) + self.sample_shape, self.dtype)
        for a, b in zip(starts, ends):
            lo, hi = int(sids[a]), int(sids[b - 1]) + 1
            arr = self.read_range(lo, hi)
            out[order[a:b]] = arr[sids[a:b] - lo]
        return out

    def reset_counters(self) -> None:
        with self._stats_lock:
            self.trace.clear()
            self.bytes_read = 0
            self.read_calls = 0

    def close(self) -> None:
        with self._fd_lock:
            self._closed = True
        while True:  # drain + close idle descriptors; in-flight ones retire
            try:     # themselves in _release_fd once their pread finishes
                fd = self._fd_pool.get_nowait()
            except queue.Empty:
                break
            self._close_fd(fd)

    def __del__(self):  # pragma: no cover - best effort
        try:
            self.close()
        except Exception:
            pass


def create_synthetic_store(
    path: str,
    num_samples: int,
    sample_shape: tuple[int, ...],
    dtype=np.float32,
    kind: str = "arange",
    seed: int = 0,
) -> ChunkStore:
    """Synthetic scientific dataset (diffraction frames / token sequences)."""
    return ChunkStore.create(
        path,
        num_samples=num_samples,
        sample_shape=sample_shape,
        dtype=dtype,
        fill=kind,
        seed=seed,
    )
