"""``memory`` backend: the dataset staged entirely into host RAM.

Models the ideal lower bound every PFS optimization chases — node-local DRAM
with zero per-call latency — and doubles as the fastest fixture for tests.
Opening a path stages the ``binary`` layout's flat file into one array
(create writes that layout first, so memory stores are reopenable); use
:meth:`MemoryBackend.from_array` to wrap an existing array without touching
disk.  ``simulated_latency_s`` still applies per coalesced read, so the
memory backend can also emulate a remote store whose *call* cost dominates
while its bandwidth is infinite.
"""
from __future__ import annotations

import json

import numpy as np

from repro.data.backends.base import BaseBackend, DatasetSpec, register_backend
from repro.data.storage import _HEADER_SUFFIX, ChunkStore


@register_backend("memory")
class MemoryBackend(BaseBackend):
    """Whole dataset resident in one ``[num_samples, *sample_shape]`` array."""

    def __init__(
        self,
        path: str | None = None,
        *,
        data: np.ndarray | None = None,
        simulated_latency_s: float = 0.0,
    ):
        if data is None:
            if path is None:
                raise ValueError("MemoryBackend needs a path or a data array")
            with open(path + _HEADER_SUFFIX) as f:
                hdr = json.load(f)
            shape = (int(hdr["num_samples"]),) + tuple(hdr["sample_shape"])
            data = np.fromfile(path, dtype=np.dtype(hdr["dtype"])).reshape(shape)
        super().__init__(
            data.shape[0],
            data.shape[1:],
            data.dtype,
            path=path or "<memory>",
            simulated_latency_s=simulated_latency_s,
        )
        self._data = data

    @classmethod
    def from_array(cls, data: np.ndarray, **options) -> "MemoryBackend":
        return cls(data=data, **options)

    @classmethod
    def create(
        cls,
        path: str,
        *,
        spec: DatasetSpec | None = None,
        data: np.ndarray | None = None,
        fill: str = "zeros",
        seed: int = 0,
        **options,
    ) -> "MemoryBackend":
        # Persist the binary layout at ``path`` so the store is reopenable,
        # then stage it: bytes on disk and in RAM are identical by design.
        from repro.data.backends.binary import write_layout

        write_layout(path, spec, data, fill, seed, "memory")
        return cls(path, **options)

    @classmethod
    def exists(cls, path: str) -> bool:
        return ChunkStore.exists(path)

    def _read_span(self, start: int, stop: int) -> np.ndarray:
        # copy: callers may hold rows past subsequent reads/close().
        return self._data[start:stop].copy()

    # -- ingest ----------------------------------------------------------------

    @property
    def writable(self) -> bool:
        return self._data.flags.writeable

    def write_rows(self, start: int, rows: np.ndarray) -> None:
        """In-RAM row overwrite (streaming ingest, DESIGN.md §10).

        Writes land in the staged array only — same-process readers see them
        immediately; the on-disk binary layout (if any) is untouched, so a
        multi-process streaming run must use a file-backed writable backend
        (``sharded``) instead.
        """
        rows = self._check_write(int(start), rows)
        self._data[start : start + rows.shape[0]] = rows

    # No _close_resources override: close() only flips _closed (new reads
    # fail loudly) while the array stays valid for reads already in flight —
    # the same "in-flight reads finish, new ones fail" contract the fd/handle
    # pools give the other backends.  RAM is reclaimed when the backend is
    # garbage collected.
