"""``binary`` backend: the flat-file :class:`ChunkStore` behind the protocol.

The PR-1 store already satisfies :class:`~repro.data.backends.base.
StorageBackend` (it *is* a :class:`~repro.data.backends.base.BaseBackend`);
this subclass only adds the uniform spec-based creation surface the registry
expects, so ``open_store(path, "binary")`` and ``create_store(path, "binary",
spec=...)`` round-trip.
"""
from __future__ import annotations

import numpy as np

from repro.data.backends.base import DatasetSpec, register_backend
from repro.data.storage import ChunkStore, write_binary_layout


def write_layout(
    path: str,
    spec: DatasetSpec | None,
    data: np.ndarray | None,
    fill: str,
    seed: int,
    kind: str,
) -> None:
    """Spec/data dispatch onto :func:`write_binary_layout` (shared with the
    ``memory`` backend, whose persisted form is this same layout)."""
    if spec is None and data is None:
        raise ValueError(f"{kind} create needs a DatasetSpec or a data array")
    if data is not None:
        write_binary_layout(path, data)
    else:
        write_binary_layout(
            path,
            num_samples=spec.num_samples,
            sample_shape=spec.sample_shape,
            dtype=spec.np_dtype,
            fill=fill,
            seed=seed,
        )


@register_backend("binary")
class BinaryBackend(ChunkStore):
    """Flat binary file + JSON header; lock-free fd-pool preads."""

    @classmethod
    def create(
        cls,
        path: str,
        *,
        spec: DatasetSpec | None = None,
        data: np.ndarray | None = None,
        fill: str = "zeros",
        seed: int = 0,
        **options,
    ) -> "BinaryBackend":
        write_layout(path, spec, data, fill, seed, "binary")
        return cls(path, **options)
