"""``sharded`` backend: samples split contiguously across multiple files.

Real multi-node PFS datasets are rarely one file — they are directories of
shards (one per writer rank / acquisition run).  Each shard here is a full
flat-binary :class:`~repro.data.storage.ChunkStore` with its *own* fd pool,
so parallel chunk fetches against different shards never contend on one
descriptor set, and a ranged read that crosses a shard boundary splits into
one pread per shard touched (honest PFS-call accounting: ``read_calls``
counts physical preads, not logical ranges).

Layout on disk for ``path``:

  * ``path + ".shards.json"`` — ``num_samples``/``sample_shape``/``dtype``
    plus ``shard_sizes`` (samples per shard, in global order), and
  * ``path + ".shardNNNNN"`` (+ its ChunkStore header) per shard — each a
    standalone, independently-openable binary store.
"""
from __future__ import annotations

import json
import os
import threading

import numpy as np

from repro.data.backends.base import (
    CoalescingReadsMixin,
    DatasetSpec,
    register_backend,
    synthetic_blocks,
)
from repro.data.storage import _HEADER_SUFFIX, ChunkStore

_SHARDS_SUFFIX = ".shards.json"


def _shard_path(path: str, k: int) -> str:
    return f"{path}.shard{k:05d}"


@register_backend("sharded")
class ShardedBackend(CoalescingReadsMixin):
    """Multi-file shards; one :class:`ChunkStore` (fd pool) per shard."""

    backend_name = "sharded"

    def __init__(self, path: str, simulated_latency_s: float = 0.0):
        self.path = path
        with open(path + _SHARDS_SUFFIX) as f:
            hdr = json.load(f)
        self.num_samples = int(hdr["num_samples"])
        self.sample_shape = tuple(hdr["sample_shape"])
        self.dtype = np.dtype(hdr["dtype"])
        self.sample_bytes = int(
            self.dtype.itemsize * int(np.prod(self.sample_shape, dtype=np.int64))
        )
        sizes = [int(s) for s in hdr["shard_sizes"]]
        self.shards = [
            ChunkStore(_shard_path(path, k), simulated_latency_s=simulated_latency_s)
            for k in range(len(sizes))
        ]
        #: global start id of each shard, plus a trailing ``num_samples``.
        self._starts = np.concatenate([[0], np.cumsum(sizes, dtype=np.int64)])
        assert int(self._starts[-1]) == self.num_samples
        self._latency = float(simulated_latency_s)
        self._closed = False
        # Streaming-ingest write path: one lazily-opened r+b descriptor per
        # shard, serialized under a lock (readers pread their own fd pools).
        self._write_lock = threading.Lock()
        self._write_fds: dict[int, object] = {}

    # -- protocol: geometry + stats (delegated to the shards) -----------------

    def spec(self) -> DatasetSpec:
        return DatasetSpec(
            self.num_samples,
            self.sample_shape,
            self.dtype.str,
            num_shards=len(self.shards),
        )

    @property
    def simulated_latency_s(self) -> float:
        return self._latency

    @simulated_latency_s.setter
    def simulated_latency_s(self, value: float) -> None:
        self._latency = float(value)
        for s in self.shards:
            s.simulated_latency_s = self._latency

    @property
    def bytes_read(self) -> int:
        return sum(s.bytes_read for s in self.shards)

    @property
    def read_calls(self) -> int:
        return sum(s.read_calls for s in self.shards)

    @property
    def trace(self) -> list[tuple[int, int]]:
        out: list[tuple[int, int]] = []
        for s, base in zip(self.shards, self._starts.tolist()):
            out.extend((base + off, n) for off, n in s.trace)
        return out

    def reset_counters(self) -> None:
        for s in self.shards:
            s.reset_counters()

    # -- reads -----------------------------------------------------------------

    def read_range(self, start: int, stop: int) -> np.ndarray:
        """Ranged read; a span crossing shard boundaries costs one pread per
        shard touched."""
        if not 0 <= start < stop <= self.num_samples:
            raise IndexError((start, stop, self.num_samples))
        if self._closed:
            raise ValueError(f"store {self.path!r} is closed")
        k = int(np.searchsorted(self._starts, start, side="right")) - 1
        parts = []
        pos = int(start)
        while pos < stop:
            base, end = int(self._starts[k]), int(self._starts[k + 1])
            hi = min(int(stop), end)
            parts.append(self.shards[k].read_range(pos - base, hi - base))
            pos = hi
            k += 1
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    # -- ingest (streaming writers, DESIGN.md §10) -----------------------------

    @property
    def writable(self) -> bool:
        return True

    def write_rows(self, start: int, rows: np.ndarray) -> None:
        """Overwrite samples ``[start, start + len(rows))`` across shards.

        Writes go straight to the shard files (unbuffered), so same-host
        reader processes pread-ing the same inodes observe the new bytes —
        the property the distributed streaming runtime relies on.  Callers
        must :meth:`flush` before publishing a sealed manifest.
        """
        start = int(start)
        rows = np.ascontiguousarray(
            np.asarray(rows, self.dtype).reshape((-1,) + self.sample_shape)
        )
        stop = start + rows.shape[0]
        if not 0 <= start <= stop <= self.num_samples:
            raise IndexError((start, stop, self.num_samples))
        if self._closed:
            raise ValueError(f"store {self.path!r} is closed")
        if start == stop:
            return
        with self._write_lock:
            k = int(np.searchsorted(self._starts, start, side="right")) - 1
            pos = start
            while pos < stop:
                base, end = int(self._starts[k]), int(self._starts[k + 1])
                hi = min(stop, end)
                f = self._write_fds.get(k)
                if f is None:
                    f = open(_shard_path(self.path, k), "r+b", buffering=0)
                    self._write_fds[k] = f
                f.seek((pos - base) * self.sample_bytes)
                f.write(rows[pos - start : hi - start].tobytes())
                pos = hi
                k += 1

    def flush(self) -> None:
        with self._write_lock:
            for f in self._write_fds.values():
                os.fsync(f.fileno())

    # -- lifecycle -------------------------------------------------------------

    def close(self) -> None:
        self._closed = True
        with self._write_lock:
            for f in self._write_fds.values():
                try:
                    f.close()
                except OSError:  # pragma: no cover - best effort
                    pass
            self._write_fds.clear()
        for s in self.shards:
            s.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # pragma: no cover - best effort
        try:
            self.close()
        except Exception:
            pass

    # -- construction ----------------------------------------------------------

    @classmethod
    def create(
        cls,
        path: str,
        *,
        spec: DatasetSpec | None = None,
        data: np.ndarray | None = None,
        fill: str = "zeros",
        seed: int = 0,
        num_shards: int | None = None,
        **options,
    ) -> "ShardedBackend":
        if data is not None:
            spec = DatasetSpec(
                data.shape[0], data.shape[1:], np.dtype(data.dtype).str
            )
        if spec is None:
            raise ValueError("sharded create needs a DatasetSpec or a data array")
        n_shards = int(num_shards or spec.num_shards or 1)
        n_shards = max(1, min(n_shards, spec.num_samples))
        per = -(-spec.num_samples // n_shards)  # ceil division
        sizes = [
            min(per, spec.num_samples - k * per) for k in range(n_shards)
        ]
        sizes = [s for s in sizes if s > 0]
        starts = np.concatenate([[0], np.cumsum(sizes, dtype=np.int64)])
        with open(path + _SHARDS_SUFFIX, "w") as f:
            json.dump(
                {
                    "num_samples": spec.num_samples,
                    "sample_shape": list(spec.sample_shape),
                    "dtype": spec.dtype,
                    "shard_sizes": sizes,
                },
                f,
            )
        files = []
        try:
            for k, size in enumerate(sizes):
                sp = _shard_path(path, k)
                with open(sp + _HEADER_SUFFIX, "w") as f:
                    json.dump(
                        {
                            "num_samples": size,
                            "sample_shape": list(spec.sample_shape),
                            "dtype": spec.dtype,
                        },
                        f,
                    )
                files.append(open(sp, "wb"))
            # Stream global-order blocks across the shard boundaries, so the
            # concatenated shard bytes are identical to the binary layout.
            blocks = (
                ((0, data),)
                if data is not None
                else synthetic_blocks(
                    spec.num_samples, spec.sample_shape, spec.np_dtype, fill, seed
                )
            )
            for b_start, rows in blocks:
                b_stop = b_start + rows.shape[0]
                k = int(np.searchsorted(starts, b_start, side="right")) - 1
                pos = b_start
                while pos < b_stop:
                    hi = min(b_stop, int(starts[k + 1]))
                    np.ascontiguousarray(rows[pos - b_start : hi - b_start]).tofile(
                        files[k]
                    )
                    pos = hi
                    k += 1
        finally:
            for f in files:
                f.close()
        return cls(path, **options)

    @classmethod
    def exists(cls, path: str) -> bool:
        return os.path.exists(path + _SHARDS_SUFFIX)
