"""``hdf5`` backend: chunk-aligned, aggregated h5py reads (paper §5.4).

SOLAR "optimizes its data access pattern with HDF5 to achieve a better
parallel I/O throughput": instead of touching the dataset once per sample,
the runtime issues a few *large* reads aligned to the HDF5 chunk grid, each
covering whole chunks, and slices the wanted samples back out.  This backend
implements exactly that:

  * ``read_ranges`` first coalesces adjacent logical ranges (like every
    backend), then rounds each merged span outward to HDF5 chunk boundaries
    and merges spans whose *aligned* windows touch — so a step's ChunkReads
    that land in the same chunks cost one h5py call, and the HDF5 chunk
    cache is never re-read for partially-consumed chunks.  ``bytes_read``
    counts the aligned span (chunk waste included), mirroring the paper's
    numPFS-with-waste accounting.  Set ``align_chunks=False`` for the naive
    exact-span behaviour (the benchmark's ablation baseline).
  * the HDF5 chunk-cache size is a knob (``rdcc_nbytes``/``rdcc_nslots``,
    passed straight to :class:`h5py.File`), and
  * ``simulated_latency_s`` injects per-call latency for PFS emulation,
    slept *outside* h5py's global lock so injected latency overlaps across
    prefetch threads.

Handles follow the PR-1 fd-pool pattern: each in-flight read checks a
private ``h5py.File`` out of an on-demand pool (h5py serializes HDF5 library
calls internally, so this is about lifecycle safety — a reader never holds a
handle that ``close()`` tears down under it — not about lock-free I/O).

h5py is an *optional* dependency (see ``requirements-dev.txt``): importing
this module never fails, but constructing the backend without h5py raises a
clear ImportError, and HDF5 tests ``pytest.importorskip`` it.
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from repro.data.backends.base import BaseBackend, DatasetSpec, register_backend, synthetic_blocks

try:  # optional dependency — tier-1 must pass without it
    import h5py

    HAVE_H5PY = True
except Exception:  # pragma: no cover - environment without h5py
    h5py = None
    HAVE_H5PY = False

__all__ = ["Hdf5Backend", "HAVE_H5PY"]

_DATASET = "samples"


def _require_h5py() -> None:
    if not HAVE_H5PY:
        raise ImportError(
            "the 'hdf5' storage backend requires h5py, which is not installed; "
            "install the optional dev dependency (see requirements-dev.txt) or "
            "pick another backend ('binary', 'sharded', 'memory')"
        )


@register_backend("hdf5")
class Hdf5Backend(BaseBackend):
    """Chunked HDF5 dataset with aggregated chunk-aligned ranged reads."""

    def __init__(
        self,
        path: str,
        *,
        simulated_latency_s: float = 0.0,
        align_chunks: bool = True,
        rdcc_nbytes: int | None = None,
        rdcc_nslots: int | None = None,
    ):
        _require_h5py()
        self._open_kwargs: dict = {}
        if rdcc_nbytes is not None:
            self._open_kwargs["rdcc_nbytes"] = int(rdcc_nbytes)
        if rdcc_nslots is not None:
            self._open_kwargs["rdcc_nslots"] = int(rdcc_nslots)
        with h5py.File(path, "r") as f:
            d = f[_DATASET]
            shape, dtype = d.shape, d.dtype
            chunk_rows = int(d.chunks[0]) if d.chunks else 0
        super().__init__(
            shape[0],
            shape[1:],
            dtype,
            path=path,
            simulated_latency_s=simulated_latency_s,
        )
        #: aggregated access on the chunk grid (paper §5.4); False = naive
        #: exact-span reads (ablation baseline in ``benchmarks/backends.py``).
        self.align_chunks = bool(align_chunks)
        #: HDF5 chunk height in samples (0 = contiguous dataset).
        self.chunk_samples = chunk_rows
        self._handles: queue.SimpleQueue = queue.SimpleQueue()
        self._files: list = []          # every File ever opened, for close()
        self._handle_lock = threading.Lock()
        self._release_handle(self._open_handle())  # fail on a bad file now

    def spec(self) -> DatasetSpec:
        return DatasetSpec(
            self.num_samples,
            self.sample_shape,
            self.dtype.str,
            chunk_samples=self.chunk_samples,
        )

    # -- handle pool (fd-pool pattern from PR 1) -------------------------------

    def _open_handle(self):
        with self._handle_lock:
            if self._closed:
                raise ValueError(f"store {self.path!r} is closed")
            f = h5py.File(self.path, "r", **self._open_kwargs)
            self._files.append(f)
        return (f, f[_DATASET])

    def _acquire_handle(self):
        if self._closed:
            raise ValueError(f"store {self.path!r} is closed")
        try:
            return self._handles.get_nowait()
        except queue.Empty:
            return self._open_handle()

    def _release_handle(self, handle) -> None:
        if self._closed:
            self._close_file(handle[0])
        else:
            self._handles.put(handle)

    def _close_file(self, f) -> None:
        with self._handle_lock:
            if f in self._files:
                self._files.remove(f)
            else:  # already retired by a racing close()
                return
        try:
            f.close()
        except Exception:  # pragma: no cover
            pass

    def _close_resources(self) -> None:
        while True:  # drain + close idle handles; in-flight ones retire
            try:     # themselves in _release_handle once their read finishes
                handle = self._handles.get_nowait()
            except queue.Empty:
                break
            self._close_file(handle[0])

    # -- reads -----------------------------------------------------------------

    def _read_span(self, start: int, stop: int) -> np.ndarray:
        handle = self._acquire_handle()
        try:
            return np.asarray(handle[1][start:stop])
        finally:
            self._release_handle(handle)

    def read_ranges(self, ranges) -> list[np.ndarray]:
        """Aggregated chunk-aligned ranged reads.

        Adjacent-touching input ranges are merged (as everywhere), then each
        merged span is rounded outward to the HDF5 chunk grid; consecutive
        spans whose aligned windows touch or overlap collapse into a single
        dataset read covering whole chunks.  The wanted sub-ranges are sliced
        back out, preserving the one-array-per-input-range contract.
        """
        if not self.align_chunks or self.chunk_samples <= 0:
            return super().read_ranges(ranges)
        c = self.chunk_samples
        ranges = [(int(a), int(b)) for a, b in ranges]
        for a, b in ranges:
            if not 0 <= a < b <= self.num_samples:
                raise IndexError((a, b, self.num_samples))
        out: list[np.ndarray | None] = [None] * len(ranges)
        i = 0
        while i < len(ranges):
            lo, hi = ranges[i]
            alo = (lo // c) * c
            ahi = min(-(-hi // c) * c, self.num_samples)
            j = i
            while j + 1 < len(ranges):
                nlo, nhi = ranges[j + 1]
                if nlo < lo or (nlo // c) * c > ahi:
                    break  # unsorted, or next aligned window is disjoint
                ahi = max(ahi, min(-(-nhi // c) * c, self.num_samples))
                j += 1
            arr = self._pread(alo, ahi)  # one aggregated h5py call
            for k in range(i, j + 1):
                a, b = ranges[k]
                out[k] = arr[a - alo : b - alo]
            i = j + 1
        return out  # type: ignore[return-value]

    # -- construction ----------------------------------------------------------

    @classmethod
    def create(
        cls,
        path: str,
        *,
        spec: DatasetSpec | None = None,
        data: np.ndarray | None = None,
        fill: str = "zeros",
        seed: int = 0,
        chunk_samples: int | None = None,
        **options,
    ) -> "Hdf5Backend":
        _require_h5py()
        if data is not None:
            spec = DatasetSpec(
                data.shape[0], data.shape[1:], np.dtype(data.dtype).str
            )
        if spec is None:
            raise ValueError("hdf5 create needs a DatasetSpec or a data array")
        rows = int(chunk_samples or spec.chunk_samples) or max(
            1, min(spec.num_samples, (1 << 20) // max(spec.sample_bytes, 1))
        )
        rows = max(1, min(rows, spec.num_samples))
        with h5py.File(path, "w") as f:
            d = f.create_dataset(
                _DATASET,
                shape=(spec.num_samples,) + spec.sample_shape,
                dtype=spec.np_dtype,
                chunks=(rows,) + spec.sample_shape,
            )
            if data is not None:
                d[...] = data
            else:
                for start, block in synthetic_blocks(
                    spec.num_samples, spec.sample_shape, spec.np_dtype, fill, seed
                ):
                    d[start : start + block.shape[0]] = block
        return cls(path, **options)

    @classmethod
    def exists(cls, path: str) -> bool:
        # signature check, not a bare stat: a flat-binary file left at the
        # same path by another backend must read as "no HDF5 dataset here"
        # (create will then raise/replace) instead of failing deep in h5py.
        return HAVE_H5PY and bool(h5py.is_hdf5(path))
