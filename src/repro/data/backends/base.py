"""Storage-backend protocol: one read contract over many physical layouts.

The SOLAR schedule only cares about *sample geometry* — which contiguous
runs of sample ids a node reads per step — never about how those samples are
laid out on disk.  This module pins that boundary down:

  * :class:`DatasetSpec` — pure geometry (sample count/shape/dtype plus the
    layout hints ``chunk_samples`` and ``num_shards``) shared by every
    backend and by dataset creation.
  * :class:`StorageBackend` — the runtime protocol every backend satisfies:
    ranged / coalesced / scattered reads, access-trace counters, a
    ``simulated_latency_s`` PFS-emulation knob, and an open/close lifecycle
    safe under the fd-pool parallel reads of the prefetch executor.
  * :class:`BaseBackend` — the shared engine.  Subclasses implement one
    physical primitive, :meth:`BaseBackend._read_span`, and inherit bounds
    checks, latency injection, stats, adjacency coalescing in
    ``read_ranges`` and run coalescing in ``read_scattered`` — so every
    backend returns bit-identical arrays and comparable counters for the
    same access plan.
  * a tiny registry (:func:`register_backend` / :func:`open_store` /
    :func:`create_store`) that :class:`repro.data.pipeline.LoaderSpec`
    resolves backend names through.

Concrete layouts live next door: ``binary`` (flat file + fd pool),
``hdf5`` (chunk-aligned aggregated h5py reads), ``memory`` (RAM-staged),
``sharded`` (multi-file, one fd pool per shard).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Iterator, Protocol, runtime_checkable

import numpy as np

from repro.obs import trace as obs_trace

__all__ = [
    "DatasetSpec",
    "StorageBackend",
    "CoalescingReadsMixin",
    "BaseBackend",
    "synthetic_blocks",
    "register_backend",
    "backend_names",
    "get_backend",
    "open_store",
    "create_store",
]


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """Geometry of one dataset, independent of the physical layout."""

    num_samples: int
    sample_shape: tuple[int, ...]
    dtype: str = "<f4"
    #: preferred contiguous-read granularity in samples (HDF5 chunk rows);
    #: 0 means the layout is fully contiguous / has no preferred alignment.
    chunk_samples: int = 0
    #: number of physical files holding the samples (sharded layouts).
    num_shards: int = 1

    def __post_init__(self):
        object.__setattr__(self, "num_samples", int(self.num_samples))
        object.__setattr__(
            self, "sample_shape", tuple(int(x) for x in self.sample_shape)
        )
        object.__setattr__(self, "dtype", np.dtype(self.dtype).str)
        object.__setattr__(self, "chunk_samples", int(self.chunk_samples))
        object.__setattr__(self, "num_shards", int(self.num_shards))

    @property
    def np_dtype(self) -> np.dtype:
        return np.dtype(self.dtype)

    @property
    def sample_bytes(self) -> int:
        return int(
            self.np_dtype.itemsize * int(np.prod(self.sample_shape, dtype=np.int64))
        )

    @property
    def nbytes(self) -> int:
        return self.num_samples * self.sample_bytes


@runtime_checkable
class StorageBackend(Protocol):
    """What the loaders, prefetch executor, and benchmarks require of a store."""

    num_samples: int
    sample_shape: tuple[int, ...]
    dtype: np.dtype
    sample_bytes: int
    #: per-physical-read sleep emulating remote-PFS call latency.
    simulated_latency_s: float
    #: access trace: (sample_offset, run_length) per physical read.
    trace: list
    bytes_read: int
    read_calls: int

    def spec(self) -> DatasetSpec: ...

    def read_range(self, start: int, stop: int) -> np.ndarray: ...

    def read_one(self, idx: int) -> np.ndarray: ...

    def read_ranges(self, ranges) -> list: ...

    def read_scattered(self, ids) -> np.ndarray: ...

    def reset_counters(self) -> None: ...

    def close(self) -> None: ...


class CoalescingReadsMixin:
    """Derived read paths on top of :meth:`read_range`.

    Mixed into anything exposing ``read_range``/``sample_shape``/``dtype``:
    adjacency coalescing for ranged reads and run coalescing for scattered
    reads, exactly as the PR-1 ``ChunkStore`` did — kept in one place so
    every backend coalesces identically.
    """

    def read_one(self, idx: int) -> np.ndarray:
        return self.read_range(idx, idx + 1)[0]

    def read_ranges(self, ranges) -> list[np.ndarray]:
        """Ranged reads with adjacency coalescing.

        ``ranges`` is a sequence of ``(start, stop)`` pairs.  Consecutive
        pairs whose spans touch (``prev_stop == next_start``) are merged into
        one physical read and split back afterwards, so a run of adjacent
        :class:`~repro.core.plan.ChunkRead`\\ s costs a single PFS call.
        Returns one array per input range, in input order.
        """
        ranges = [(int(a), int(b)) for a, b in ranges]
        out: list[np.ndarray | None] = [None] * len(ranges)
        i = 0
        while i < len(ranges):
            j = i
            while j + 1 < len(ranges) and ranges[j + 1][0] == ranges[j][1]:
                j += 1
            lo, hi = ranges[i][0], ranges[j][1]
            arr = self.read_range(lo, hi)
            for k in range(i, j + 1):
                a, b = ranges[k]
                out[k] = arr[a - lo : b - lo]
            i = j + 1
        return out  # type: ignore[return-value]

    def read_scattered(self, ids) -> np.ndarray:
        """Scattered read of k samples, coalescing consecutive ids.

        Ids are sorted, runs of adjacent ids become ranged reads (routed
        through :meth:`read_ranges`, so backends with smarter ranged paths —
        e.g. HDF5 chunk alignment — benefit here too), and rows come back in
        the caller's original order (duplicates allowed).
        """
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size == 0:
            return np.empty((0,) + tuple(self.sample_shape), self.dtype)
        order = np.argsort(ids, kind="stable")
        sids = ids[order]
        breaks = np.flatnonzero(np.diff(sids) > 1) + 1
        starts = np.concatenate([[0], breaks])
        ends = np.concatenate([breaks, [sids.size]])
        runs = [(int(sids[a]), int(sids[b - 1]) + 1) for a, b in zip(starts, ends)]
        arrays = self.read_ranges(runs)
        out = np.empty((ids.size,) + tuple(self.sample_shape), self.dtype)
        for a, b, arr, (lo, _) in zip(starts, ends, arrays, runs):
            out[order[a:b]] = arr[sids[a:b] - lo]
        return out


class BaseBackend(CoalescingReadsMixin):
    """Shared geometry + stats + latency engine for storage backends.

    Subclasses implement :meth:`_read_span` (one physical contiguous read of
    samples ``[start, stop)``) and optionally :meth:`_close_resources`.
    Everything else — bounds checks, per-read latency injection, the access
    trace, and both coalescing read paths — is inherited.
    """

    backend_name = "base"

    def __init__(
        self,
        num_samples: int,
        sample_shape: tuple[int, ...],
        dtype,
        *,
        path: str = "<anonymous>",
        simulated_latency_s: float = 0.0,
    ):
        self.path = path
        self.num_samples = int(num_samples)
        self.sample_shape = tuple(int(x) for x in sample_shape)
        self.dtype = np.dtype(dtype)
        self.sample_bytes = int(
            self.dtype.itemsize * int(np.prod(self.sample_shape, dtype=np.int64))
        )
        #: per-physical-read sleep emulating remote-PFS call latency
        #: (``time.sleep`` releases the GIL, so injected latency overlaps
        #: across prefetch threads exactly like real PFS round-trips would).
        self.simulated_latency_s = float(simulated_latency_s)
        self._closed = False
        self._stats_lock = threading.Lock()
        #: access trace: list of (sample_offset, run_length) — consumed by
        #: the cost model and the access-pattern benchmark; cheap to record.
        self.trace: list[tuple[int, int]] = []
        self.bytes_read = 0
        self.read_calls = 0

    # -- protocol surface ------------------------------------------------------

    def spec(self) -> DatasetSpec:
        return DatasetSpec(self.num_samples, self.sample_shape, self.dtype.str)

    def read_range(self, start: int, stop: int) -> np.ndarray:
        """One ranged read: samples [start, stop) in a single physical call."""
        if not 0 <= start < stop <= self.num_samples:
            raise IndexError((start, stop, self.num_samples))
        return self._pread(int(start), int(stop))

    def reset_counters(self) -> None:
        with self._stats_lock:
            self.trace.clear()
            self.bytes_read = 0
            self.read_calls = 0

    # -- ingest (streaming writers, DESIGN.md §10) -----------------------------

    @property
    def writable(self) -> bool:
        """Whether :meth:`write_rows` is supported (streaming ingest)."""
        return False

    def write_rows(self, start: int, rows: np.ndarray) -> None:
        """Overwrite samples ``[start, start + len(rows))`` in place.

        Only writable backends (``memory``, ``sharded``) implement this; the
        store is pre-sized, so ingest never grows or shrinks the id space.
        """
        raise NotImplementedError(
            f"{self.backend_name!r} backend is read-only; streaming ingest "
            "needs a writable backend ('memory' or 'sharded')"
        )

    def flush(self) -> None:
        """Make prior :meth:`write_rows` durable/visible to other processes."""

    def _check_write(self, start: int, rows: np.ndarray) -> np.ndarray:
        if self._closed:
            raise ValueError(f"store {self.path!r} is closed")
        rows = np.ascontiguousarray(
            np.asarray(rows, self.dtype).reshape((-1,) + self.sample_shape)
        )
        stop = start + rows.shape[0]
        if not 0 <= start <= stop <= self.num_samples:
            raise IndexError((start, stop, self.num_samples))
        return rows

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        self._closed = True
        self._close_resources()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # pragma: no cover - best effort
        try:
            self.close()
        except Exception:
            pass

    # -- physical layer --------------------------------------------------------

    def _pread(self, start: int, stop: int) -> np.ndarray:
        """One physical read: latency injection + the span read + stats."""
        if self._closed:
            raise ValueError(f"store {self.path!r} is closed")
        tr = obs_trace.get()
        t0 = tr.t()
        if self.simulated_latency_s > 0.0:
            time.sleep(self.simulated_latency_s)
        arr = self._read_span(start, stop)
        tr.rec(obs_trace.CHUNK_READ, t0, a=stop - start,
               b=(stop - start) * self.sample_bytes)
        with self._stats_lock:
            self.trace.append((start, stop - start))
            self.bytes_read += (stop - start) * self.sample_bytes
            self.read_calls += 1
        return arr

    def _read_span(self, start: int, stop: int) -> np.ndarray:
        """Physically read samples ``[start, stop)`` — one call per invocation."""
        raise NotImplementedError

    def _close_resources(self) -> None:
        """Tear down descriptors/handles; called once from :meth:`close`."""


# ---------------------------------------------------------------------------
# Synthetic data generation (shared so every backend stores identical bytes)
# ---------------------------------------------------------------------------


def synthetic_blocks(
    num_samples: int,
    sample_shape: tuple[int, ...],
    dtype,
    fill: str = "zeros",
    seed: int = 0,
    block: int = 4096,
) -> Iterator[tuple[int, np.ndarray]]:
    """Yield ``(start, rows)`` blocks of deterministic synthetic data.

    One RNG stream across blocks, fixed block size: the concatenated output
    depends only on ``(num_samples, sample_shape, dtype, fill, seed)`` — never
    on which backend consumes the blocks — so backend-parity tests can compare
    stores bit-for-bit.
    """
    sample_shape = tuple(int(x) for x in sample_shape)
    sample_elems = int(np.prod(sample_shape, dtype=np.int64))
    dtype = np.dtype(dtype)
    rng = np.random.Generator(np.random.PCG64(seed))
    for start in range(0, num_samples, block):
        n = min(block, num_samples - start)
        if fill == "zeros":
            arr = np.zeros((n, sample_elems), dtype)
        elif fill == "random":
            if np.issubdtype(dtype, np.integer):
                arr = rng.integers(0, 255, size=(n, sample_elems)).astype(dtype)
            else:
                arr = rng.standard_normal((n, sample_elems)).astype(dtype)
        elif fill == "arange":
            # sample i filled with value i — lets tests verify reads.
            arr = np.broadcast_to(
                np.arange(start, start + n, dtype=np.int64)[:, None],
                (n, sample_elems),
            ).astype(dtype)
        else:
            raise ValueError(f"unknown fill {fill!r}")
        yield start, arr.reshape((n,) + sample_shape)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type] = {}

#: built-in backends, resolved lazily on first use — keeps this module free
#: of imports from the concrete layouts (which import ChunkStore, which
#: imports this module).
_LAZY_BACKENDS = {
    "binary": "repro.data.backends.binary",
    "hdf5": "repro.data.backends.hdf5",
    "memory": "repro.data.backends.memory",
    "sharded": "repro.data.backends.sharded",
}


def register_backend(name: str):
    """Class decorator: register a backend under ``name`` (its CLI/spec id)."""

    def _register(cls):
        cls.backend_name = name
        _REGISTRY[name] = cls
        return cls

    return _register


def backend_names() -> list[str]:
    return sorted(set(_REGISTRY) | set(_LAZY_BACKENDS))


def get_backend(name: str) -> type:
    if name not in _REGISTRY and name in _LAZY_BACKENDS:
        import importlib

        importlib.import_module(_LAZY_BACKENDS[name])  # registers itself
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown storage backend {name!r}; have {backend_names()}"
        ) from None


def open_store(path: str, backend: str = "binary", **options):
    """Open an existing dataset at ``path`` through the named backend."""
    return get_backend(backend)(path, **options)


def create_store(
    path: str,
    backend: str = "binary",
    *,
    spec: DatasetSpec | None = None,
    data: np.ndarray | None = None,
    fill: str = "zeros",
    seed: int = 0,
    **options,
):
    """Create a dataset at ``path`` in the named backend's layout and open it.

    Provide either ``data`` (an ``[num_samples, *sample_shape]`` array) or a
    :class:`DatasetSpec` plus a ``fill`` kind (``zeros``/``random``/``arange``)
    for synthetic generation.  Extra ``options`` go to the backend (both
    creation-time layout knobs and open-time options).
    """
    return get_backend(backend).create(
        path, spec=spec, data=data, fill=fill, seed=seed, **options
    )
