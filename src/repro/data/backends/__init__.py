"""Pluggable storage backends behind one :class:`StorageBackend` protocol.

Four built-in layouts resolve through the registry:

  ========== ===========================================================
  ``binary``  flat file + JSON header, lock-free fd-pool preads (PR 1)
  ``hdf5``    chunked h5py dataset, chunk-aligned aggregated reads
              (optional dependency — construction fails without h5py)
  ``memory``  dataset staged into host RAM (ideal lower bound / tests)
  ``sharded`` multi-file shards, one fd pool per shard (multi-node realism)
  ========== ===========================================================

Open / create through the registry (:func:`open_store` /
:func:`create_store`) or declaratively through
:class:`repro.data.pipeline.LoaderSpec`.  Concrete backend classes are
imported lazily (``from repro.data.backends import Hdf5Backend`` works, but
the submodule loads on first access) so that ``repro.data.storage`` —
which the ``binary`` backend wraps — can import :mod:`.base` without a
cycle.
"""
from repro.data.backends.base import (
    BaseBackend,
    CoalescingReadsMixin,
    DatasetSpec,
    StorageBackend,
    backend_names,
    create_store,
    get_backend,
    open_store,
    register_backend,
    synthetic_blocks,
)

_LAZY_EXPORTS = {
    "BinaryBackend": ("repro.data.backends.binary", "BinaryBackend"),
    "Hdf5Backend": ("repro.data.backends.hdf5", "Hdf5Backend"),
    "HAVE_H5PY": ("repro.data.backends.hdf5", "HAVE_H5PY"),
    "MemoryBackend": ("repro.data.backends.memory", "MemoryBackend"),
    "ShardedBackend": ("repro.data.backends.sharded", "ShardedBackend"),
}


def __getattr__(name):  # PEP 562: lazy submodule exports
    if name in _LAZY_EXPORTS:
        import importlib

        module, attr = _LAZY_EXPORTS[name]
        return getattr(importlib.import_module(module), attr)
    raise AttributeError(name)


__all__ = [
    "BaseBackend",
    "BinaryBackend",
    "CoalescingReadsMixin",
    "DatasetSpec",
    "HAVE_H5PY",
    "Hdf5Backend",
    "MemoryBackend",
    "ShardedBackend",
    "StorageBackend",
    "backend_names",
    "create_store",
    "get_backend",
    "open_store",
    "register_backend",
    "synthetic_blocks",
]
