"""Peer-fetch runtime: serving planned inter-node buffer fetches.

The offline scheduler records, per node-step, which misses are served from a
sibling node's buffer instead of the PFS (:class:`~repro.core.plan.PeerFetch`,
DESIGN.md §6).  This module executes those fetches behind one transport
interface:

  * :class:`SharedViewTransport` — the in-process emulation used by the
    loader zoo and the benchmarks: every "node" is a
    :class:`~repro.data.loaders._DataMirror` in this process, so a fetch is
    a vectorized arena gather.  This is the semantic reference: digest
    parity against the PFS path is proved against it.
  * :class:`SocketTransport` — the real deployment transport: every node
    runs a :class:`~repro.runtime.server.BufferServer` over its buffer
    arena, and a fetch is one framed request/response round trip on the
    training interconnect (:mod:`repro.runtime.wire` — length-prefixed
    frames, SHA-256 checksums, geometry negotiation on connect).  Any wire
    failure — truncated frame, checksum mismatch, dead peer, a stale-step
    refusal from the server — degrades to "nothing served" and the loader
    re-reads from the PFS; only a *geometry* disagreement fails loudly
    (:class:`~repro.runtime.wire.HandshakeError`), because silently
    PFS-falling-back forever would mask a misconfigured deployment.

Ordering contract: all of a step's peer fetches must be issued against the
buffer state at the *start* of the step — i.e. before any node applies that
step's admission/eviction deltas — because the plan guarantees residency
only at step start (the source may evict the sample in the same step).
:meth:`repro.data.loaders.ScheduleExecutor.gather_peers` upholds this by
gathering every node's peer rows before ``execute_step`` touches a mirror.

Samples a transport cannot produce (possible only if the ordering contract
is broken, or a remote node died) are *not* errors here: the exchange
reports them as fallbacks and the loader re-reads them from the PFS, so the
tier degrades to correctness-preserving slow paths, never wrong bytes.
"""
from __future__ import annotations

import contextlib
import dataclasses
import random
import socket
import time
from typing import Callable, Mapping, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.plan import PeerFetch
from repro.obs import trace as obs_trace

__all__ = [
    "AddressBookError",
    "PeerTransport",
    "RetryPolicy",
    "Breaker",
    "SharedViewTransport",
    "SocketTransport",
    "PeerExchange",
]


class AddressBookError(ValueError):
    """An invalid peer address book: duplicate ``(host, port)`` endpoints,
    a node's own endpoint listed as a peer, or an out-of-range port."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """The graded failure ladder for socket peer fetches (DESIGN.md §9).

    Rung 1 — **retry**: a failed fetch (dial error, wire error, refusal) is
    retried up to ``max_attempts`` times total, sleeping an exponentially
    growing backoff with seeded jitter between attempts.  Transient blips
    (one reset, one corrupt frame) cost one retry, not a PFS fallback.

    Rung 2 — **circuit breaker**, per source: ``breaker_threshold``
    *consecutive* exhausted fetches open the breaker; while open, fetches to
    that source short-circuit straight to PFS fallback (no dial, no
    hammering a struggling peer).  After ``breaker_cooldown_s`` the breaker
    goes half-open and admits exactly one probe fetch — success closes it,
    failure re-opens it.

    Rung 3 — **escalation**: once the breaker has opened
    ``escalate_after`` times without an intervening success, the transport
    invokes its escalation callback (the launcher routes this to the control
    plane's suspect path).  The coordinator — which sees heartbeats the data
    plane does not — arbitrates; the transport never declares anyone dead.

    All sleeps derive from ``seed`` so a chaos run's timing is reproducible.
    """

    max_attempts: int = 2
    backoff_base_s: float = 0.02
    backoff_max_s: float = 0.25
    jitter: float = 0.5
    breaker_threshold: int = 3
    breaker_cooldown_s: float = 0.5
    escalate_after: int = 2
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.breaker_threshold < 1:
            raise ValueError(
                f"breaker_threshold must be >= 1, got {self.breaker_threshold}"
            )

    def backoff_s(self, attempt: int, rng: random.Random) -> float:
        """Backoff before retry ``attempt`` (0-based): exp growth + jitter."""
        base = min(self.backoff_base_s * (2 ** attempt), self.backoff_max_s)
        return base * (1.0 + self.jitter * rng.random())


class _Breaker:
    """Per-source circuit breaker state machine (clock injected for tests)."""

    def __init__(self, policy: RetryPolicy):
        self.policy = policy
        self.state = "closed"
        self.failures = 0
        self.opened_at = 0.0
        self.opens_in_row = 0

    def allow(self, now: float) -> bool:
        """May we attempt a fetch right now?  Open→half-open on cooldown."""
        if self.state == "open":
            if now - self.opened_at >= self.policy.breaker_cooldown_s:
                self.state = "half_open"
                return True
            return False
        return True

    def success(self) -> None:
        self.state = "closed"
        self.failures = 0
        self.opens_in_row = 0

    def failure(self, now: float) -> bool:
        """Record an exhausted fetch; True when this transition *opened*."""
        self.failures += 1
        if self.state == "half_open" or self.failures >= self.policy.breaker_threshold:
            self.state = "open"
            self.opened_at = now
            self.failures = 0
            self.opens_in_row += 1
            return True
        return False


#: Public alias: the serve tier's ``DataTierClient`` drives the same
#: per-endpoint breaker state machine the trainer transport does
#: (DESIGN.md §12) — one ladder, two consumers.
Breaker = _Breaker


@runtime_checkable
class PeerTransport(Protocol):
    """One fetch primitive: rows of ``ids`` out of ``source``'s buffer.

    Returns ``(rows, ok)`` where ``ok`` is a boolean mask over ``ids`` and
    ``rows`` holds one row per True entry, in ``ids[ok]`` order.
    """

    def fetch(
        self, source: int, ids: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]: ...


class SharedViewTransport:
    """In-process transport over the per-node buffer mirrors.

    ``mirror_of`` resolves a node id to its live
    :class:`~repro.data.loaders._DataMirror` (the loader passes its own
    accessor, so mirrors created lazily are always current).  Rows are
    copied out of the arena (numpy fancy indexing), so later evictions on
    the source cannot corrupt an already-fetched batch.
    """

    def __init__(self, mirror_of: Callable[[int], object]):
        self._mirror_of = mirror_of

    def fetch(self, source: int, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        mirror = self._mirror_of(source)
        slots = mirror.lookup(np.asarray(ids, np.int64))
        ok = slots >= 0
        return mirror.rows(slots[ok]), ok


class SocketTransport:
    """Socket-RPC transport over per-node buffer servers.

    ``endpoints`` maps *peer* node id -> ``(host, port)`` of that node's
    :class:`~repro.runtime.server.BufferServer`.  The address book is
    validated up front with named errors (:class:`AddressBookError`):
    duplicate ``(host, port)`` pairs (two nodes cannot share one server),
    ``self_node`` listed among the peers (a node never dials itself — its
    own samples are served straight from the local mirror via
    ``mirror_of``), and out-of-range ports.

    One persistent connection per source, established lazily with a
    geometry handshake (expected node id, sample shape, dtype — the server
    refuses a mismatched client, and the mismatch raises
    :class:`~repro.runtime.wire.HandshakeError` here).  :meth:`at_step`
    stamps subsequent fetches with the requester's global step index, which
    the serving side uses as its step-epoch guard.

    Failure semantics follow the graded ladder in :class:`RetryPolicy`:
    bounded retries with backoff+jitter, then a per-source circuit breaker
    (open → temporary PFS routing → half-open probe → close), then
    escalation through ``escalate`` (the launcher's suspect path) once the
    breaker trips persistently.  Every rung is counted (``retries``,
    ``breaker_opens``, ``breaker_skips``, ``escalations``,
    ``unknown_source_fallbacks``) and surfaced through :meth:`stats` into
    ``LoaderReport.summary()``.  The failed connection is dropped and
    redialed on the next allowed fetch, so a restarted peer is picked back
    up automatically.

    The book is *dynamic*: the launcher's recovery path calls
    :meth:`update_endpoints` when node ownership moves to a different
    surviving rank, and :meth:`add_local` when *this* rank adopts a node —
    from then on that node's rows come from the adopted local mirror, not a
    socket.
    """

    def __init__(
        self,
        endpoints: Mapping[int, tuple[str, int]],
        *,
        timeout_s: float = 1.0,
        self_node: int | None = None,
        mirror_of: Callable[[int], object] | None = None,
        sample_shape: tuple[int, ...] | None = None,
        dtype=None,
        retry: RetryPolicy | None = None,
        escalate: Callable[[int], None] | None = None,
    ):
        self.endpoints = {
            int(node): (str(host), int(port))
            for node, (host, port) in endpoints.items()
        }
        self.timeout_s = float(timeout_s)
        self.self_node = None if self_node is None else int(self_node)
        self._mirror_of = mirror_of
        self.sample_shape = (
            None if sample_shape is None
            else tuple(int(x) for x in sample_shape)
        )
        self.dtype = None if dtype is None else np.dtype(dtype)
        self._step = -1
        self._window: int | None = None
        self._conns: dict[int, socket.socket] = {}
        self.retry = retry if retry is not None else RetryPolicy()
        self._escalate = escalate
        self._local: set[int] = set()
        self._breakers: dict[int, _Breaker] = {}
        self._rngs: dict[int, random.Random] = {}
        self.retries = 0
        self.breaker_opens = 0
        self.breaker_skips = 0
        self.escalations = 0
        self.unknown_source_fallbacks = 0
        #: fetches that ended in a peer's *stale refusal* (window-skew guard
        #: or an ownership transition) — expected under skew, so they fall
        #: back to the PFS without charging the breaker/escalation ladder.
        self.stale_refusal_fallbacks = 0
        errs = []
        seen: dict[tuple[str, int], int] = {}
        for node in sorted(self.endpoints):
            host, port = self.endpoints[node]
            if not 0 < port < 65536:
                errs.append(f"node {node}: port {port} out of range [1, 65535]")
            if (host, port) in seen:
                errs.append(
                    f"duplicate endpoint {(host, port)} for nodes "
                    f"{seen[host, port]} and {node}"
                )
            seen[host, port] = node
        if self.self_node is not None and self.self_node in self.endpoints:
            errs.append(
                f"self-endpoint: node {self.self_node} lists itself as a "
                "peer — local samples are served from the local mirror, "
                "never over a socket"
            )
        if errs:
            raise AddressBookError(
                "invalid peer address book: " + "; ".join(errs)
            )

    def at_step(self, step: int, window: int | None = None) -> None:
        """Stamp subsequent fetches with the requester's global step index
        (the serving side's step-epoch guard, DESIGN.md §8).  With
        ``window`` given, fetches ride the windowed frame (``MSG_FETCHW``)
        so the serving side applies the window-skew guard instead of the
        exact-step guard (DESIGN.md §11)."""
        self._step = int(step)
        self._window = None if window is None else int(window)

    # -- elastic membership (launcher recovery path) ------------------------

    def update_endpoints(self, moved: Mapping[int, tuple[str, int]]) -> None:
        """Re-point sources whose owner changed (re-slice / rejoin).

        Pooled connections and breaker state for a moved source are
        discarded: the new owner starts with a clean slate.
        """
        for node, (host, port) in moved.items():
            node = int(node)
            if node == self.self_node or node in self._local:
                continue
            ep = (str(host), int(port))
            if self.endpoints.get(node) == ep:
                continue
            self.endpoints[node] = ep
            conn = self._conns.pop(node, None)
            if conn is not None:
                with contextlib.suppress(OSError):
                    conn.close()
            self._breakers.pop(node, None)

    def add_local(self, node: int) -> None:
        """This rank now owns ``node``: serve it from the local mirror."""
        node = int(node)
        self._local.add(node)
        self.endpoints.pop(node, None)
        conn = self._conns.pop(node, None)
        if conn is not None:
            with contextlib.suppress(OSError):
                conn.close()
        self._breakers.pop(node, None)

    def remove_local(self, node: int) -> None:
        """Ownership of ``node`` moved away (a rejoined rank reclaimed it)."""
        self._local.discard(int(node))

    def stats(self) -> dict:
        """Failure-ladder counters for ``LoaderReport`` aggregation."""
        return {
            "retries": self.retries,
            "breaker_opens": self.breaker_opens,
            "breaker_skips": self.breaker_skips,
            "escalations": self.escalations,
            "unknown_source_fallbacks": self.unknown_source_fallbacks,
            "stale_refusal_fallbacks": self.stale_refusal_fallbacks,
        }

    def _breaker(self, source: int) -> _Breaker:
        br = self._breakers.get(source)
        if br is None:
            br = self._breakers[source] = _Breaker(self.retry)
        return br

    def _rng(self, source: int) -> random.Random:
        rng = self._rngs.get(source)
        if rng is None:
            rng = self._rngs[source] = random.Random(
                (self.retry.seed << 17) ^ (source * 1000003 + 7)
            )
        return rng

    def close(self) -> None:
        """Drop every pooled connection (idempotent)."""
        conns, self._conns = self._conns, {}
        for conn in conns.values():
            with contextlib.suppress(OSError):
                conn.close()

    def __enter__(self) -> "SocketTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _fallback(self, n: int) -> tuple[np.ndarray, np.ndarray]:
        shape = self.sample_shape or ()
        dtype = self.dtype if self.dtype is not None else np.float32
        return np.empty((0,) + tuple(shape), dtype), np.zeros(n, bool)

    def _connect(self, source: int) -> socket.socket:
        from repro.runtime import faults, wire

        if faults.on_dial():
            raise ConnectionResetError(
                f"injected connection reset dialing peer {source}"
            )
        host, port = self.endpoints[source]
        conn = socket.create_connection((host, port), timeout=self.timeout_s)
        conn.settimeout(self.timeout_s)
        try:
            wire.send_frame(conn, wire.MSG_HELLO, wire.pack_json({
                "node": int(source),
                "shape": list(self.sample_shape),
                "dtype": self.dtype.str,
            }))
            msg_type, payload = wire.recv_frame(conn)
            if msg_type == wire.MSG_ERROR:
                reason = payload.decode(errors="replace")
                if "geometry mismatch" in reason:
                    # deployment misconfiguration: fail loudly, never retry.
                    raise wire.HandshakeError(
                        f"peer {source} refused the handshake: {reason}"
                    )
                if "not serving node" in reason:
                    # mid ownership transition (window-edge re-slice or a
                    # rejoin reclaim): expected under the epoch-window
                    # protocol — retriable, but never a breaker fault.
                    raise wire.StaleRefusal(
                        f"peer {source} refused the handshake: {reason}"
                    )
                # any other refusal is transient: retriable wire error.
                raise wire.ProtocolError(
                    f"peer {source} refused the handshake: {reason}"
                )
            if msg_type != wire.MSG_HELLO_OK:
                raise wire.ProtocolError(
                    f"expected HELLO_OK from peer {source}, got {msg_type}"
                )
        except BaseException:
            with contextlib.suppress(OSError):
                conn.close()
            raise
        return conn

    def fetch(self, source: int, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        from repro.runtime import wire

        ids = np.asarray(ids, np.int64)
        if self.sample_shape is None or self.dtype is None:
            raise ValueError(
                "SocketTransport needs sample_shape and dtype (the store "
                "geometry) to decode row frames — construct it with both "
                "to fetch; endpoint-only construction is for config "
                "validation"
            )
        if (
            source == self.self_node or source in self._local
        ) and self._mirror_of is not None:
            # own (or adopted) holder: a zero-cost local arena gather,
            # never a socket.
            mirror = self._mirror_of(source)
            if mirror is not None:
                slots = mirror.lookup(ids)
                ok = slots >= 0
                if not ok.any():
                    return self._fallback(ids.size)[0], ok
                return mirror.rows(slots[ok]), ok
            return self._fallback(ids.size)
        if source not in self.endpoints:
            # a peer missing from the address book (died before registering,
            # or a misconfigured book): serve nothing, the loader falls back
            # to the PFS — counted so misconfiguration is visible, not slow.
            self.unknown_source_fallbacks += 1
            return self._fallback(ids.size)
        tr = obs_trace.get()
        breaker = self._breaker(source)
        if not breaker.allow(time.monotonic()):
            # breaker open: temporary PFS routing, no dial at all.
            self.breaker_skips += 1
            tr.instant(obs_trace.PEER_BREAKER_SKIP, a=source)
            return self._fallback(ids.size)
        t0 = tr.t()
        rng = self._rng(source)
        pooled = self._conns.pop(source, None)
        # A pooled connection may have been idled out by the server between
        # steps — staleness, not a dead peer — so it rides in front of the
        # policy's fresh-dial attempts and its failure costs a retry, not a
        # fallback.
        attempts: list[socket.socket | None] = [None] * self.retry.max_attempts
        if pooled is not None:
            attempts.insert(0, pooled)
        refused_stale = False
        for i, conn in enumerate(attempts):
            last = i == len(attempts) - 1
            try:
                if conn is None:
                    conn = self._connect(source)
                if self._window is not None:
                    wire.send_frame(
                        conn, wire.MSG_FETCHW,
                        wire.pack_fetchw(self._window, self._step, ids),
                        site="transport.fetch",
                    )
                else:
                    wire.send_frame(
                        conn, wire.MSG_FETCH, wire.pack_fetch(self._step, ids),
                        site="transport.fetch",
                    )
                msg_type, payload = wire.recv_frame(conn)
                if msg_type != wire.MSG_ROWS:
                    raise wire.ProtocolError(
                        f"expected ROWS from peer {source}, got {msg_type}"
                    )
                ok, rows = wire.unpack_rows(
                    payload, ids.size, self.sample_shape, self.dtype
                )
            except (wire.WireError, OSError) as exc:
                # truncated / corrupt / reset / dead peer: never wrong bytes
                # — drop the connection and climb the ladder.
                refused_stale = isinstance(exc, wire.StaleRefusal)
                if conn is not None:
                    with contextlib.suppress(OSError):
                        conn.close()
                if not last:
                    self.retries += 1
                    tr.instant(obs_trace.PEER_RETRY, a=source, b=i)
                    time.sleep(self.retry.backoff_s(i, rng))
                continue
            except BaseException:
                if conn is not None:
                    with contextlib.suppress(OSError):
                        conn.close()
                raise
            self._conns[source] = conn
            breaker.success()
            tr.rec(obs_trace.PEER_FETCH, t0, a=source, b=0)
            return rows, ok
        if refused_stale:
            # the final word was the peer's window-skew guard refusing —
            # expected under skew (DESIGN.md §11): PFS fallback, but no
            # breaker failure and no escalation.  Charging the ladder here
            # would open breakers (and suspect healthy ranks) every time
            # ownership moves across a window edge.
            self.stale_refusal_fallbacks += 1
            tr.rec(obs_trace.PEER_FETCH, t0, a=source, b=1)
            return self._fallback(ids.size)
        # every attempt exhausted: one breaker failure for the whole fetch.
        tr.rec(obs_trace.PEER_FETCH, t0, a=source, b=2)
        if breaker.failure(time.monotonic()):
            self.breaker_opens += 1
            tr.instant(obs_trace.PEER_BREAKER_OPEN, a=source)
            if (
                breaker.opens_in_row >= self.retry.escalate_after
                and self._escalate is not None
            ):
                self.escalations += 1
                self._escalate(source)
        return self._fallback(ids.size)


class PeerExchange:
    """Executes one node-step's planned peer fetches through a transport.

    Groups fetches by source node (one transport call per source), tracks
    served/fallback counts and per-source serve totals, and returns only the
    rows the transport produced — callers route the rest to the PFS.
    """

    def __init__(
        self,
        transport: PeerTransport,
        sample_shape: tuple[int, ...],
        dtype,
    ):
        self.transport = transport
        self.sample_shape = tuple(int(x) for x in sample_shape)
        self.dtype = np.dtype(dtype)
        self.served = 0
        self.fallbacks = 0
        #: samples served *by* each source node (serving-load accounting).
        self.served_by_source: dict[int, int] = {}

    def gather(
        self, fetches: Sequence[PeerFetch]
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Fetch every sample in ``fetches`` from its planned source.

        Returns ``(ids, rows, missing_ids)``: ``rows[i]`` is the sample
        ``ids[i]``, and ``missing_ids`` lists samples the transport could
        not serve (counted as fallbacks; the caller reads them from the
        store).
        """
        if not fetches:
            empty = np.empty(0, np.int64)
            return empty, np.empty((0,) + self.sample_shape, self.dtype), empty
        tr = obs_trace.get()
        t0 = tr.t()
        ids = np.asarray([f.sample for f in fetches], np.int64)
        srcs = np.asarray([f.source for f in fetches], np.int64)
        rows = np.empty((ids.size,) + self.sample_shape, self.dtype)
        ok_all = np.zeros(ids.size, bool)
        for src in np.unique(srcs).tolist():
            sel = np.flatnonzero(srcs == src)
            got, ok = self.transport.fetch(src, ids[sel])
            rows[sel[ok]] = got
            ok_all[sel[ok]] = True
            self.served_by_source[src] = (
                self.served_by_source.get(src, 0) + int(ok.sum())
            )
        self.served += int(ok_all.sum())
        self.fallbacks += int((~ok_all).sum())
        tr.rec(obs_trace.PEER_GATHER, t0, a=ids.size)
        return ids[ok_all], rows[ok_all], ids[~ok_all]
