"""Peer-fetch runtime: serving planned inter-node buffer fetches.

The offline scheduler records, per node-step, which misses are served from a
sibling node's buffer instead of the PFS (:class:`~repro.core.plan.PeerFetch`,
DESIGN.md §6).  This module executes those fetches behind one transport
interface:

  * :class:`SharedViewTransport` — the in-process emulation used by the
    loader zoo and the benchmarks: every "node" is a
    :class:`~repro.data.loaders._DataMirror` in this process, so a fetch is
    a vectorized arena gather.  This is the semantic reference: digest
    parity against the PFS path is proved against it.
  * :class:`SocketTransport` — the interface stub for a real deployment,
    where each node runs a serving thread over its buffer arena and fetches
    are RPCs on the training interconnect.  Construction (address book,
    knobs) works so configs can be written and validated today; ``fetch``
    raises :class:`NotImplementedError` until the wire protocol lands.

Ordering contract: all of a step's peer fetches must be issued against the
buffer state at the *start* of the step — i.e. before any node applies that
step's admission/eviction deltas — because the plan guarantees residency
only at step start (the source may evict the sample in the same step).
:meth:`repro.data.loaders.ScheduleExecutor.gather_peers` upholds this by
gathering every node's peer rows before ``execute_step`` touches a mirror.

Samples a transport cannot produce (possible only if the ordering contract
is broken, or a remote node died) are *not* errors here: the exchange
reports them as fallbacks and the loader re-reads them from the PFS, so the
tier degrades to correctness-preserving slow paths, never wrong bytes.
"""
from __future__ import annotations

from typing import Callable, Mapping, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.plan import PeerFetch

__all__ = [
    "PeerTransport",
    "SharedViewTransport",
    "SocketTransport",
    "PeerExchange",
]


@runtime_checkable
class PeerTransport(Protocol):
    """One fetch primitive: rows of ``ids`` out of ``source``'s buffer.

    Returns ``(rows, ok)`` where ``ok`` is a boolean mask over ``ids`` and
    ``rows`` holds one row per True entry, in ``ids[ok]`` order.
    """

    def fetch(
        self, source: int, ids: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]: ...


class SharedViewTransport:
    """In-process transport over the per-node buffer mirrors.

    ``mirror_of`` resolves a node id to its live
    :class:`~repro.data.loaders._DataMirror` (the loader passes its own
    accessor, so mirrors created lazily are always current).  Rows are
    copied out of the arena (numpy fancy indexing), so later evictions on
    the source cannot corrupt an already-fetched batch.
    """

    def __init__(self, mirror_of: Callable[[int], object]):
        self._mirror_of = mirror_of

    def fetch(self, source: int, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        mirror = self._mirror_of(source)
        slots = mirror.lookup(np.asarray(ids, np.int64))
        ok = slots >= 0
        return mirror.rows(slots[ok]), ok


class SocketTransport:
    """Socket-RPC transport stub: same interface, wire protocol TBD.

    ``endpoints`` maps node id -> ``(host, port)`` of that node's buffer
    server.  The constructor validates the address book so deployment
    configs can be built and round-tripped now; :meth:`fetch` raises until
    the serving side exists.
    """

    def __init__(
        self,
        endpoints: Mapping[int, tuple[str, int]],
        *,
        timeout_s: float = 1.0,
    ):
        self.endpoints = {
            int(node): (str(host), int(port))
            for node, (host, port) in endpoints.items()
        }
        self.timeout_s = float(timeout_s)

    def fetch(self, source: int, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        if source not in self.endpoints:
            raise KeyError(f"no endpoint registered for node {source}")
        raise NotImplementedError(
            "SocketTransport.fetch: the peer wire protocol is not implemented "
            "yet; use SharedViewTransport (in-process) or fall back to PFS "
            "reads by disabling peer_fetch"
        )


class PeerExchange:
    """Executes one node-step's planned peer fetches through a transport.

    Groups fetches by source node (one transport call per source), tracks
    served/fallback counts and per-source serve totals, and returns only the
    rows the transport produced — callers route the rest to the PFS.
    """

    def __init__(
        self,
        transport: PeerTransport,
        sample_shape: tuple[int, ...],
        dtype,
    ):
        self.transport = transport
        self.sample_shape = tuple(int(x) for x in sample_shape)
        self.dtype = np.dtype(dtype)
        self.served = 0
        self.fallbacks = 0
        #: samples served *by* each source node (serving-load accounting).
        self.served_by_source: dict[int, int] = {}

    def gather(
        self, fetches: Sequence[PeerFetch]
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Fetch every sample in ``fetches`` from its planned source.

        Returns ``(ids, rows, missing_ids)``: ``rows[i]`` is the sample
        ``ids[i]``, and ``missing_ids`` lists samples the transport could
        not serve (counted as fallbacks; the caller reads them from the
        store).
        """
        if not fetches:
            empty = np.empty(0, np.int64)
            return empty, np.empty((0,) + self.sample_shape, self.dtype), empty
        ids = np.asarray([f.sample for f in fetches], np.int64)
        srcs = np.asarray([f.source for f in fetches], np.int64)
        rows = np.empty((ids.size,) + self.sample_shape, self.dtype)
        ok_all = np.zeros(ids.size, bool)
        for src in np.unique(srcs).tolist():
            sel = np.flatnonzero(srcs == src)
            got, ok = self.transport.fetch(src, ids[sel])
            rows[sel[ok]] = got
            ok_all[sel[ok]] = True
            self.served_by_source[src] = (
                self.served_by_source.get(src, 0) + int(ok.sum())
            )
        self.served += int(ok_all.sum())
        self.fallbacks += int((~ok_all).sum())
        return ids[ok_all], rows[ok_all], ids[~ok_all]
