"""Peer-fetch runtime: serving planned inter-node buffer fetches.

The offline scheduler records, per node-step, which misses are served from a
sibling node's buffer instead of the PFS (:class:`~repro.core.plan.PeerFetch`,
DESIGN.md §6).  This module executes those fetches behind one transport
interface:

  * :class:`SharedViewTransport` — the in-process emulation used by the
    loader zoo and the benchmarks: every "node" is a
    :class:`~repro.data.loaders._DataMirror` in this process, so a fetch is
    a vectorized arena gather.  This is the semantic reference: digest
    parity against the PFS path is proved against it.
  * :class:`SocketTransport` — the real deployment transport: every node
    runs a :class:`~repro.runtime.server.BufferServer` over its buffer
    arena, and a fetch is one framed request/response round trip on the
    training interconnect (:mod:`repro.runtime.wire` — length-prefixed
    frames, SHA-256 checksums, geometry negotiation on connect).  Any wire
    failure — truncated frame, checksum mismatch, dead peer, a stale-step
    refusal from the server — degrades to "nothing served" and the loader
    re-reads from the PFS; only a *geometry* disagreement fails loudly
    (:class:`~repro.runtime.wire.HandshakeError`), because silently
    PFS-falling-back forever would mask a misconfigured deployment.

Ordering contract: all of a step's peer fetches must be issued against the
buffer state at the *start* of the step — i.e. before any node applies that
step's admission/eviction deltas — because the plan guarantees residency
only at step start (the source may evict the sample in the same step).
:meth:`repro.data.loaders.ScheduleExecutor.gather_peers` upholds this by
gathering every node's peer rows before ``execute_step`` touches a mirror.

Samples a transport cannot produce (possible only if the ordering contract
is broken, or a remote node died) are *not* errors here: the exchange
reports them as fallbacks and the loader re-reads them from the PFS, so the
tier degrades to correctness-preserving slow paths, never wrong bytes.
"""
from __future__ import annotations

import contextlib
import socket
from typing import Callable, Mapping, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.plan import PeerFetch

__all__ = [
    "AddressBookError",
    "PeerTransport",
    "SharedViewTransport",
    "SocketTransport",
    "PeerExchange",
]


class AddressBookError(ValueError):
    """An invalid peer address book: duplicate ``(host, port)`` endpoints,
    a node's own endpoint listed as a peer, or an out-of-range port."""


@runtime_checkable
class PeerTransport(Protocol):
    """One fetch primitive: rows of ``ids`` out of ``source``'s buffer.

    Returns ``(rows, ok)`` where ``ok`` is a boolean mask over ``ids`` and
    ``rows`` holds one row per True entry, in ``ids[ok]`` order.
    """

    def fetch(
        self, source: int, ids: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]: ...


class SharedViewTransport:
    """In-process transport over the per-node buffer mirrors.

    ``mirror_of`` resolves a node id to its live
    :class:`~repro.data.loaders._DataMirror` (the loader passes its own
    accessor, so mirrors created lazily are always current).  Rows are
    copied out of the arena (numpy fancy indexing), so later evictions on
    the source cannot corrupt an already-fetched batch.
    """

    def __init__(self, mirror_of: Callable[[int], object]):
        self._mirror_of = mirror_of

    def fetch(self, source: int, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        mirror = self._mirror_of(source)
        slots = mirror.lookup(np.asarray(ids, np.int64))
        ok = slots >= 0
        return mirror.rows(slots[ok]), ok


class SocketTransport:
    """Socket-RPC transport over per-node buffer servers.

    ``endpoints`` maps *peer* node id -> ``(host, port)`` of that node's
    :class:`~repro.runtime.server.BufferServer`.  The address book is
    validated up front with named errors (:class:`AddressBookError`):
    duplicate ``(host, port)`` pairs (two nodes cannot share one server),
    ``self_node`` listed among the peers (a node never dials itself — its
    own samples are served straight from the local mirror via
    ``mirror_of``), and out-of-range ports.

    One persistent connection per source, established lazily with a
    geometry handshake (expected node id, sample shape, dtype — the server
    refuses a mismatched client, and the mismatch raises
    :class:`~repro.runtime.wire.HandshakeError` here).  :meth:`at_step`
    stamps subsequent fetches with the requester's global step index, which
    the serving side uses as its step-epoch guard.

    Failure semantics: any :class:`~repro.runtime.wire.WireError` or socket
    error — including a peer that died mid-step or an endpoint that never
    appeared in the book — yields an all-False ok mask, so the caller falls
    back to PFS reads.  The failed connection is dropped and redialed on
    the next fetch, so a restarted peer is picked back up automatically.
    """

    def __init__(
        self,
        endpoints: Mapping[int, tuple[str, int]],
        *,
        timeout_s: float = 1.0,
        self_node: int | None = None,
        mirror_of: Callable[[int], object] | None = None,
        sample_shape: tuple[int, ...] | None = None,
        dtype=None,
    ):
        self.endpoints = {
            int(node): (str(host), int(port))
            for node, (host, port) in endpoints.items()
        }
        self.timeout_s = float(timeout_s)
        self.self_node = None if self_node is None else int(self_node)
        self._mirror_of = mirror_of
        self.sample_shape = (
            None if sample_shape is None
            else tuple(int(x) for x in sample_shape)
        )
        self.dtype = None if dtype is None else np.dtype(dtype)
        self._step = -1
        self._conns: dict[int, socket.socket] = {}
        errs = []
        seen: dict[tuple[str, int], int] = {}
        for node in sorted(self.endpoints):
            host, port = self.endpoints[node]
            if not 0 < port < 65536:
                errs.append(f"node {node}: port {port} out of range [1, 65535]")
            if (host, port) in seen:
                errs.append(
                    f"duplicate endpoint {(host, port)} for nodes "
                    f"{seen[host, port]} and {node}"
                )
            seen[host, port] = node
        if self.self_node is not None and self.self_node in self.endpoints:
            errs.append(
                f"self-endpoint: node {self.self_node} lists itself as a "
                "peer — local samples are served from the local mirror, "
                "never over a socket"
            )
        if errs:
            raise AddressBookError(
                "invalid peer address book: " + "; ".join(errs)
            )

    def at_step(self, step: int) -> None:
        """Stamp subsequent fetches with the requester's global step index
        (the serving side's step-epoch guard, DESIGN.md §8)."""
        self._step = int(step)

    def close(self) -> None:
        """Drop every pooled connection (idempotent)."""
        conns, self._conns = self._conns, {}
        for conn in conns.values():
            with contextlib.suppress(OSError):
                conn.close()

    def __enter__(self) -> "SocketTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _fallback(self, n: int) -> tuple[np.ndarray, np.ndarray]:
        shape = self.sample_shape or ()
        dtype = self.dtype if self.dtype is not None else np.float32
        return np.empty((0,) + tuple(shape), dtype), np.zeros(n, bool)

    def _connect(self, source: int) -> socket.socket:
        from repro.runtime import wire

        host, port = self.endpoints[source]
        conn = socket.create_connection((host, port), timeout=self.timeout_s)
        conn.settimeout(self.timeout_s)
        try:
            wire.send_frame(conn, wire.MSG_HELLO, wire.pack_json({
                "node": int(source),
                "shape": list(self.sample_shape),
                "dtype": self.dtype.str,
            }))
            msg_type, payload = wire.recv_frame(conn)
            if msg_type == wire.MSG_ERROR:
                raise wire.HandshakeError(
                    f"peer {source} refused the handshake: "
                    f"{payload.decode(errors='replace')}"
                )
            if msg_type != wire.MSG_HELLO_OK:
                raise wire.ProtocolError(
                    f"expected HELLO_OK from peer {source}, got {msg_type}"
                )
        except BaseException:
            with contextlib.suppress(OSError):
                conn.close()
            raise
        return conn

    def fetch(self, source: int, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        from repro.runtime import wire

        ids = np.asarray(ids, np.int64)
        if self.sample_shape is None or self.dtype is None:
            raise ValueError(
                "SocketTransport needs sample_shape and dtype (the store "
                "geometry) to decode row frames — construct it with both "
                "to fetch; endpoint-only construction is for config "
                "validation"
            )
        if source == self.self_node and self._mirror_of is not None:
            # own holder: a zero-cost local arena gather, never a socket.
            mirror = self._mirror_of(source)
            slots = mirror.lookup(ids)
            ok = slots >= 0
            if not ok.any():
                return self._fallback(ids.size)[0], ok
            return mirror.rows(slots[ok]), ok
        if source not in self.endpoints:
            # e.g. a peer that died before registering: serve nothing, the
            # loader falls back to the PFS.
            return self._fallback(ids.size)
        pooled = self._conns.pop(source, None)
        # A pooled connection may have been idled out by the server between
        # steps — that is staleness, not a dead peer, so it earns exactly
        # one retry on a fresh dial before we declare fallback.
        for conn in (pooled, None) if pooled is not None else (None,):
            try:
                if conn is None:
                    conn = self._connect(source)
                wire.send_frame(
                    conn, wire.MSG_FETCH, wire.pack_fetch(self._step, ids)
                )
                msg_type, payload = wire.recv_frame(conn)
                if msg_type != wire.MSG_ROWS:
                    raise wire.ProtocolError(
                        f"expected ROWS from peer {source}, got {msg_type}"
                    )
                ok, rows = wire.unpack_rows(
                    payload, ids.size, self.sample_shape, self.dtype
                )
            except (wire.WireError, OSError):
                # truncated / corrupt / dead peer: never wrong bytes — serve
                # nothing (or retry once off the stale pooled conn) and let
                # the caller hit the PFS.
                if conn is not None:
                    with contextlib.suppress(OSError):
                        conn.close()
                continue
            except BaseException:
                if conn is not None:
                    with contextlib.suppress(OSError):
                        conn.close()
                raise
            self._conns[source] = conn
            return rows, ok
        return self._fallback(ids.size)


class PeerExchange:
    """Executes one node-step's planned peer fetches through a transport.

    Groups fetches by source node (one transport call per source), tracks
    served/fallback counts and per-source serve totals, and returns only the
    rows the transport produced — callers route the rest to the PFS.
    """

    def __init__(
        self,
        transport: PeerTransport,
        sample_shape: tuple[int, ...],
        dtype,
    ):
        self.transport = transport
        self.sample_shape = tuple(int(x) for x in sample_shape)
        self.dtype = np.dtype(dtype)
        self.served = 0
        self.fallbacks = 0
        #: samples served *by* each source node (serving-load accounting).
        self.served_by_source: dict[int, int] = {}

    def gather(
        self, fetches: Sequence[PeerFetch]
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Fetch every sample in ``fetches`` from its planned source.

        Returns ``(ids, rows, missing_ids)``: ``rows[i]`` is the sample
        ``ids[i]``, and ``missing_ids`` lists samples the transport could
        not serve (counted as fallbacks; the caller reads them from the
        store).
        """
        if not fetches:
            empty = np.empty(0, np.int64)
            return empty, np.empty((0,) + self.sample_shape, self.dtype), empty
        ids = np.asarray([f.sample for f in fetches], np.int64)
        srcs = np.asarray([f.source for f in fetches], np.int64)
        rows = np.empty((ids.size,) + self.sample_shape, self.dtype)
        ok_all = np.zeros(ids.size, bool)
        for src in np.unique(srcs).tolist():
            sel = np.flatnonzero(srcs == src)
            got, ok = self.transport.fetch(src, ids[sel])
            rows[sel[ok]] = got
            ok_all[sel[ok]] = True
            self.served_by_source[src] = (
                self.served_by_source.get(src, 0) + int(ok.sum())
            )
        self.served += int(ok_all.sum())
        self.fallbacks += int((~ok_all).sum())
        return ids[ok_all], rows[ok_all], ids[~ok_all]
