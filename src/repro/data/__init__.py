"""Data substrate: pluggable storage backends, the plan-first loader
pipeline, and the async device-feed executor.

Typical entry point::

    from repro.data import DatasetSpec, LoaderSpec, build_pipeline, create_store

    store = create_store(path, "hdf5", spec=DatasetSpec(16384, (1024,)))
    pipeline = build_pipeline(LoaderSpec(loader="solar", store=store, ...))

or, with the plan made explicit (precompute once, execute many)::

    from repro.data import plan, execute

    schedule = plan(spec)              # -> repro.core.plan.Schedule artifact
    pipeline = execute(spec, schedule)
"""
from repro.core.planners import PLANNERS, STRATEGIES, PlanCache
from repro.data.backends import (
    DatasetSpec,
    StorageBackend,
    backend_names,
    create_store,
    get_backend,
    open_store,
)
from repro.data.loaders import (
    LoaderReport,
    ScheduleExecutor,
    StepBatch,
    stream_digest,
    update_batch_digest,
)
from repro.data.peer import (
    AddressBookError,
    PeerExchange,
    SharedViewTransport,
    SocketTransport,
)
from repro.data.pipeline import (
    LoaderSpec,
    build_pipeline,
    build_store,
    execute,
    make_planner,
    plan,
)
from repro.data.prefetch import PrefetchExecutor
from repro.data.storage import ChunkStore, create_synthetic_store

__all__ = [
    "AddressBookError",
    "ChunkStore",
    "DatasetSpec",
    "LoaderSpec",
    "StorageBackend",
    "backend_names",
    "build_pipeline",
    "build_store",
    "create_store",
    "create_synthetic_store",
    "execute",
    "get_backend",
    "make_planner",
    "open_store",
    "plan",
    "PeerExchange",
    "PrefetchExecutor",
    "SharedViewTransport",
    "SocketTransport",
    "LoaderReport",
    "PlanCache",
    "PLANNERS",
    "STRATEGIES",
    "ScheduleExecutor",
    "StepBatch",
    "stream_digest",
    "update_batch_digest",
]
