"""Data substrate: pluggable storage backends, loader zoo, and the async
device-feed pipeline.

Typical entry point::

    from repro.data import DatasetSpec, LoaderSpec, build_pipeline, create_store

    store = create_store(path, "hdf5", spec=DatasetSpec(16384, (1024,)))
    pipeline = build_pipeline(LoaderSpec(loader="solar", store=store, ...))
"""
from repro.data.backends import (
    DatasetSpec,
    StorageBackend,
    backend_names,
    create_store,
    get_backend,
    open_store,
)
from repro.data.loaders import (
    LOADERS,
    DeepIOLoader,
    LoaderReport,
    LRULoader,
    NaiveLoader,
    NoPFSLoader,
    SolarLoader,
    StepBatch,
)
from repro.data.peer import PeerExchange, SharedViewTransport, SocketTransport
from repro.data.pipeline import LoaderSpec, build_pipeline, build_store
from repro.data.prefetch import PrefetchExecutor
from repro.data.storage import ChunkStore, create_synthetic_store

__all__ = [
    "ChunkStore",
    "DatasetSpec",
    "LoaderSpec",
    "StorageBackend",
    "backend_names",
    "build_pipeline",
    "build_store",
    "create_store",
    "create_synthetic_store",
    "get_backend",
    "open_store",
    "PeerExchange",
    "PrefetchExecutor",
    "SharedViewTransport",
    "SocketTransport",
    "DeepIOLoader",
    "LoaderReport",
    "LOADERS",
    "LRULoader",
    "NaiveLoader",
    "NoPFSLoader",
    "SolarLoader",
    "StepBatch",
]
