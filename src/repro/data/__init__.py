"""Data substrate: chunked sample store ("PFS"), loaders, and the device
feed pipeline."""
from repro.data.loaders import (
    DeepIOLoader,
    LoaderReport,
    LRULoader,
    NaiveLoader,
    NoPFSLoader,
    SolarLoader,
    StepBatch,
    make_loader,
)
from repro.data.prefetch import PrefetchExecutor
from repro.data.storage import ChunkStore, create_synthetic_store

__all__ = [
    "ChunkStore",
    "create_synthetic_store",
    "PrefetchExecutor",
    "DeepIOLoader",
    "LoaderReport",
    "LRULoader",
    "NaiveLoader",
    "NoPFSLoader",
    "SolarLoader",
    "StepBatch",
    "make_loader",
]
