"""The schedule executor: one runtime replays any strategy's plan.

Every loading strategy — SOLAR and all four baselines — compiles offline to
the same :class:`~repro.core.plan.Schedule` IR (see
:mod:`repro.core.planners`), so the runtime no longer needs a zoo of loader
classes improvising their access order inside ``__iter__``.  One
:class:`ScheduleExecutor` replays any plan against any
:class:`~repro.data.backends.base.StorageBackend`:

  * buffer hits come out of a per-node :class:`_DataMirror` arena,
  * misses ride the plan's coalesced :class:`~repro.core.plan.ChunkRead`
    ranged reads (``store.read_ranges``),
  * planned :class:`~repro.core.plan.PeerFetch` records are served through a
    :class:`~repro.data.peer.PeerExchange` when a transport is configured
    (SOLAR's interconnect tier, DESIGN.md §6) and fall back to coalesced
    scattered store reads otherwise (how NoPFS's emulated remote fetches are
    billed without a transport),
  * buffer state is maintained purely from the plan's recorded
    admission/eviction deltas — the runtime never re-decides.

The executor yields :class:`StepBatch` objects and accumulates a
:class:`LoaderReport` with numPFS / modeled PFS time / wall time, which is
what the paper's figures plot.  ``fast_forward(n)`` replays the first ``n``
steps' deltas without reading data — mid-epoch resume from a checkpointed
plan cursor costs no I/O.

Construct executors declaratively via :func:`repro.data.pipeline.plan` /
:func:`~repro.data.pipeline.execute` (or their composition
:func:`~repro.data.pipeline.build_pipeline`).
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time

import numpy as np

from repro.core.costmodel import PeerCostModel, PFSCostModel
from repro.core.plan import Schedule
from repro.data.backends.base import StorageBackend

__all__ = [
    "StepBatch",
    "LoaderReport",
    "ScheduleExecutor",
    "update_batch_digest",
    "stream_digest",
]


@dataclasses.dataclass
class StepBatch:
    epoch: int
    step: int
    #: per-node real sample ids.
    node_ids: list[np.ndarray]
    #: per-node sample arrays, [num_real, *sample_shape]; None when counting only.
    node_data: list[np.ndarray] | None
    #: per-node hit masks (True = served from buffer).
    hit_masks: list[np.ndarray]

    def to_global(self, capacity: int):
        """Pad each node to ``capacity`` rows and stack: SPMD-ready batch.

        Returns ``(data, weights)`` with shapes ``[N*capacity, ...]`` and
        ``[N*capacity]``; dummy rows have weight 0 so the masked loss makes
        gradients identical to the unpadded batch (DESIGN.md §3).
        """
        assert self.node_data is not None
        n = len(self.node_ids)
        shape = self.node_data[0].shape[1:]
        dtype = self.node_data[0].dtype
        data = np.zeros((n, capacity) + shape, dtype)
        weights = np.zeros((n, capacity), np.float32)
        for i, arr in enumerate(self.node_data):
            k = min(arr.shape[0], capacity)
            data[i, :k] = arr[:k]
            weights[i, :k] = 1.0
        return data.reshape((n * capacity,) + shape), weights.reshape(-1)


def update_batch_digest(h, sb: StepBatch) -> None:
    """Feed one batch's canonical bytes (epoch, step, ids, masks, data) to
    a hashlib object — the digest the parity tests and benchmarks pin."""
    h.update(np.int64(sb.epoch).tobytes())
    h.update(np.int64(sb.step).tobytes())
    for ids, mask in zip(sb.node_ids, sb.hit_masks):
        h.update(np.ascontiguousarray(ids, dtype=np.int64).tobytes())
        h.update(np.ascontiguousarray(mask, dtype=bool).tobytes())
    if sb.node_data is not None:
        for arr in sb.node_data:
            h.update(np.ascontiguousarray(arr).tobytes())


def stream_digest(batches) -> str:
    """SHA-256 over a whole :class:`StepBatch` stream, canonical encoding."""
    h = hashlib.sha256()
    for sb in batches:
        update_batch_digest(h, sb)
    return h.hexdigest()


@dataclasses.dataclass
class LoaderReport:
    name: str
    num_nodes: int
    #: per-(step, node) PFS sample counts (misses incl. chunk waste).
    pfs_counts: list[list[int]] = dataclasses.field(default_factory=list)
    #: per-(step, node) PFS miss counts (wanted samples only; misses served
    #: from a remote buffer are in ``remote_counts`` instead).
    miss_counts: list[list[int]] = dataclasses.field(default_factory=list)
    #: per-(step, node) remote-buffer fetch counts (NoPFS online fetches /
    #: SOLAR planned peer fetches).
    remote_counts: list[list[int]] = dataclasses.field(default_factory=list)
    #: per-(step, node) batch sizes.
    batch_sizes: list[list[int]] = dataclasses.field(default_factory=list)
    modeled_time_s: float = 0.0
    wall_time_s: float = 0.0
    total_hits: int = 0
    total_samples: int = 0
    #: samples served *by* each source node over the peer tier (serving-load
    #: accounting, mirrored from :attr:`PeerExchange.served_by_source` —
    #: read imbalance lives in ``pfs_counts``, serving imbalance lives here).
    served_by_source: dict = dataclasses.field(default_factory=dict)
    #: failure-ladder counters mirrored from the transport after each gather
    #: (``retries`` / ``breaker_opens`` / ``unknown_source_fallbacks`` / ...);
    #: empty for transports without a ladder (shared-view).
    transport_stats: dict = dataclasses.field(default_factory=dict)

    @property
    def total_pfs(self) -> int:
        return int(np.sum(self.pfs_counts)) if self.pfs_counts else 0

    @property
    def total_misses(self) -> int:
        return int(np.sum(self.miss_counts)) if self.miss_counts else 0

    @property
    def hit_rate(self) -> float:
        return self.total_hits / self.total_samples if self.total_samples else 0.0

    @property
    def total_remote(self) -> int:
        return int(np.sum(self.remote_counts)) if self.remote_counts else 0

    @property
    def max_step_pfs(self) -> np.ndarray:
        a = np.asarray(self.pfs_counts)
        if a.ndim < 2 or a.shape[1] == 0:
            # a rank whose plan slice is empty records zero-node steps
            return np.zeros(len(self.pfs_counts), np.int64)
        return a.max(axis=1)

    def summary(self) -> dict:
        return {
            "loader": self.name,
            "numPFS": self.total_pfs,
            "misses": self.total_misses,
            "remote_fetches": self.total_remote,
            "peer_served_by_source": {
                str(k): int(v) for k, v in sorted(self.served_by_source.items())
            },
            "hit_rate": round(self.hit_rate, 4),
            "modeled_time_s": round(self.modeled_time_s, 3),
            "wall_time_s": round(self.wall_time_s, 3),
            # the transport failure ladder (zeros for ladder-less transports)
            "retries": int(self.transport_stats.get("retries", 0)),
            "breaker_opens": int(self.transport_stats.get("breaker_opens", 0)),
            "unknown_source_fallbacks": int(
                self.transport_stats.get("unknown_source_fallbacks", 0)
            ),
        }


class _DataMirror:
    """Array-backed mirror of one node's buffer contents (id -> sample row).

    Lookups are vectorized (sorted id array + ``np.searchsorted``); admissions
    copy only the admitted rows into free slots of a preallocated arena and
    evictions only release slots — there is no per-step rebuild of the buffer.
    """

    def __init__(self, capacity: int, sample_shape: tuple[int, ...], dtype):
        self.capacity = max(int(capacity), 1)
        self._sample_shape = sample_shape
        self._dtype = dtype
        self._data: np.ndarray | None = None  # allocated on first admit
        self.ids = np.empty(0, np.int64)      # sorted
        self._slots = np.empty(0, np.int64)   # parallel to ids
        self._free = list(range(self.capacity - 1, -1, -1))
        #: optional list capturing ``(ids, rows)`` of everything evicted —
        #: the BufferServer's window-skew guard (DESIGN.md §11) binds it
        #: around a step's delta replay so peers still inside the skew
        #: window can be served rows this step just evicted.
        self.evict_sink: list | None = None

    def lookup(self, want: np.ndarray) -> np.ndarray:
        """Arena slot per wanted id, -1 where absent."""
        want = np.asarray(want, np.int64)
        if want.size == 0 or self.ids.size == 0:
            return np.full(want.size, -1, np.int64)
        pos = np.minimum(np.searchsorted(self.ids, want), self.ids.size - 1)
        return np.where(self.ids[pos] == want, self._slots[pos], -1)

    def rows(self, slots: np.ndarray) -> np.ndarray:
        assert self._data is not None
        return self._data[slots]

    def evict(self, ids) -> None:
        ids = np.asarray(ids, np.int64)
        if ids.size == 0 or self.ids.size == 0:
            return
        keep = ~np.isin(self.ids, ids, assume_unique=True)
        if self.evict_sink is not None and self._data is not None:
            gone = ~keep
            if gone.any():
                self.evict_sink.append(
                    (self.ids[gone].copy(), self._data[self._slots[gone]].copy())
                )
        self._free.extend(int(s) for s in self._slots[~keep].tolist())
        self.ids = self.ids[keep]
        self._slots = self._slots[keep]

    def admit(self, ids, rows) -> None:
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return
        present = self.lookup(ids) >= 0
        if present.any():  # re-admission of a resident id is a no-op
            ids, rows = ids[~present], rows[~present]
            if ids.size == 0:
                return
        if self._data is None:
            self._data = np.empty(
                (self.capacity,) + self._sample_shape, self._dtype
            )
        slots = np.asarray([self._free.pop() for _ in range(ids.size)], np.int64)
        self._data[slots] = rows
        all_ids = np.concatenate([self.ids, ids])
        all_slots = np.concatenate([self._slots, slots])
        order = np.argsort(all_ids, kind="stable")
        self.ids = all_ids[order]
        self._slots = all_slots[order]


class ScheduleExecutor:
    """Replay one :class:`~repro.core.plan.Schedule` against one store.

    The executor is strategy-agnostic: everything it does — which samples a
    node trains, which bytes come from the buffer / a peer / the PFS, what
    enters and leaves the buffer — is recorded in the plan.  Peer serving is
    enabled by passing ``solar_config`` with ``enable_peer`` set (the
    pipeline layer does this) or an explicit ``peer_transport``; without
    either, planned peer fetches are billed as remote transfers but the
    bytes come from coalesced scattered store reads — which is exactly how
    the NoPFS baseline's emulated hierarchical fetches behave.
    """

    def __init__(
        self,
        store: StorageBackend,
        schedule: Schedule,
        *,
        collect_data: bool = False,
        cost_model: PFSCostModel | None = None,
        peer_cost: PeerCostModel | None = None,
        peer_transport=None,
        solar_config=None,
        serve_peers: bool | None = None,
    ):
        self.store = store
        self.schedule = schedule
        self.name = schedule.strategy
        self.num_nodes = schedule.num_nodes
        self.local_batch = schedule.local_batch
        self.num_epochs = len(schedule.epochs)
        self.buffer_size = schedule.buffer_size
        self.collect_data = collect_data
        self.cost = cost_model or PFSCostModel(sample_bytes=store.sample_bytes)
        self.solar_config = solar_config
        #: streaming mode (DESIGN.md §10): while open, a plan walk that runs
        #: out of epochs waits for extend() instead of finishing.
        self._stream_cond = threading.Condition()
        self._stream_open = False
        self.stream_timeout_s = 60.0
        if serve_peers is None:
            serve_peers = peer_transport is not None or bool(
                solar_config is not None and solar_config.enable_peer
            )
        if peer_cost is None and solar_config is not None:
            peer_cost = solar_config.peer_cost
        if serve_peers and peer_cost is None:
            # price the peer tier with this store's real sample size
            peer_cost = PeerCostModel(
                sample_bytes=store.sample_bytes, pfs=self.cost
            )
        self.peer_cost = peer_cost
        self.report = LoaderReport(name=self.name, num_nodes=self.num_nodes)
        #: per-node data buffers (actual arrays) when materializing batches.
        self._data_buf: list[_DataMirror | None] = [None] * self.num_nodes
        #: buffer occupancy per node, maintained from the plan's recorded
        #: admission/eviction deltas — no per-step resident-set rebuild.
        self._occupancy = [0] * self.num_nodes
        #: first plan step to *execute*; earlier steps replay deltas only.
        self._start_step = 0
        self.peer_exchange = None
        if serve_peers:
            from repro.data.peer import PeerExchange, SharedViewTransport

            self.peer_exchange = PeerExchange(
                peer_transport or SharedViewTransport(self._mirror),
                self.store.sample_shape,
                self.store.dtype,
            )

    @property
    def capacity(self) -> int:
        return self.schedule.capacity

    @property
    def config_hash(self) -> str:
        return self.schedule.config_hash

    def remote_time(self, k: int, interconnect_bps: float = 1.0e10,
                    latency_s: float = 5e-5) -> float:
        if self.peer_cost is not None:
            return self.peer_cost.fetch_time(k)
        return k * (latency_s + self.store.sample_bytes / interconnect_bps)

    # -- plan walking ---------------------------------------------------------

    def reset_execution(self) -> None:
        """Forget buffer state so the schedule can be replayed from step 0."""
        self._occupancy = [0] * self.num_nodes
        self._data_buf = [None] * self.num_nodes

    def fast_forward(self, num_steps: int) -> None:
        """Start subsequent iterations at plan step ``num_steps``.

        The skipped steps' admission/eviction deltas are replayed without
        reading any batch data or accounting anything; then, when data is
        being collected, each node's buffer is re-staged with **one**
        coalesced scattered read of its resident set — so a resumed run pays
        a single bounded buffer refill instead of re-reading every skipped
        batch, and every later planned hit is served from RAM exactly as in
        an uninterrupted run.  Resumed batches stay bit-identical either
        way (an unstaged row would fall back to a store read).
        """
        self._start_step = max(int(num_steps), 0)

    def _skip_step(self, sp, resident: list[set]) -> None:
        for npn in sp.nodes:
            r = npn.node
            self._occupancy[r] += npn.admissions.size - npn.evictions.size
            resident[r].update(npn.admissions.tolist())
            resident[r].difference_update(npn.evictions.tolist())

    def _restage_buffers(self, resident: list[set]) -> None:
        """Refill the data mirrors after a fast-forward: one coalesced
        scattered read per node covering exactly its resident samples."""
        for r, ids in enumerate(resident):
            if not ids:
                continue
            ordered = np.fromiter(ids, np.int64, count=len(ids))
            ordered.sort()
            self._mirror(r).admit(ordered, self.store.read_scattered(ordered))

    def begin_stream(self) -> None:
        """Enter streaming mode: plan walks block at the end of the schedule
        (waiting for :meth:`extend`) instead of finishing."""
        with self._stream_cond:
            self._stream_open = True

    def finish_stream(self) -> None:
        """Leave streaming mode: blocked walks drain and finish normally."""
        with self._stream_cond:
            self._stream_open = False
            self._stream_cond.notify_all()

    def extend(self, schedule: Schedule) -> None:
        """Chain another plan segment onto the live schedule, no teardown.

        The appended segment must match the running schedule's geometry and
        strategy; its epochs join the walk in order.  Safe to call from a
        different thread than the one iterating (the streaming driver plans
        window ``k+1`` while the executor replays window ``k``): the epoch
        list is only appended to, and walks pick up appended epochs under
        the stream condition.
        """
        for field in ("num_nodes", "local_batch", "capacity", "buffer_size",
                      "strategy"):
            if getattr(schedule, field) != getattr(self.schedule, field):
                raise ValueError(
                    f"extend(): segment {field} "
                    f"{getattr(schedule, field)!r} != running "
                    f"{getattr(self.schedule, field)!r}"
                )
        with self._stream_cond:
            self.schedule.epochs.extend(schedule.epochs)
            self.schedule.epoch_order = np.concatenate(
                [
                    np.asarray(self.schedule.epoch_order, np.int64),
                    np.asarray(schedule.epoch_order, np.int64),
                ]
            )
            self.num_epochs = len(self.schedule.epochs)
            self._stream_cond.notify_all()

    def stream_steps_ready(self) -> int | None:
        """Yieldable plan steps currently materialized, or None when not in
        streaming mode (non-streaming walks never block).

        The prefetch pipeline probes this before pulling another step for
        its read-ahead window: when the walk would block waiting for the
        next ``extend()``, the pipeline assembles the steps it already holds
        instead of stalling the whole pipe at a window boundary.
        """
        with self._stream_cond:
            if not self._stream_open:
                return None
            total = sum(len(ep.steps) for ep in self.schedule.epochs)
            return max(total - self._start_step, 0)

    def _next_epoch(self, ei: int):
        """Epoch ``ei``, or None past the end — waiting in streaming mode."""
        with self._stream_cond:
            if ei < len(self.schedule.epochs):
                return self.schedule.epochs[ei]
            if not self._stream_open:
                return None
            deadline = time.monotonic() + self.stream_timeout_s
            while ei >= len(self.schedule.epochs) and self._stream_open:
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"streaming walk waited > {self.stream_timeout_s}s "
                        f"for window {ei} (extend() never arrived)"
                    )
                self._stream_cond.wait(0.05)
            if ei < len(self.schedule.epochs):
                return self.schedule.epochs[ei]
            return None  # stream finished while waiting

    def plan_steps(self):
        """Walk the schedule in execution order, yielding (EpochPlan, StepPlan).

        This is the surface the :class:`repro.data.prefetch.PrefetchExecutor`
        pipelines over: every future ChunkRead is visible here.  Each walk
        replays the buffer simulation from an empty buffer, honoring
        :meth:`fast_forward`.  The walk is index-based so epochs appended by
        :meth:`extend` mid-walk are picked up; in streaming mode it blocks
        at the end of the schedule until the next window or
        :meth:`finish_stream`.
        """
        self.reset_execution()
        idx = 0
        resident: list[set] = [set() for _ in range(self.num_nodes)]
        staged = self._start_step == 0
        ei = 0
        while True:
            ep = self._next_epoch(ei)
            if ep is None:
                return
            for sp in ep.steps:
                if idx < self._start_step:
                    self._skip_step(sp, resident)
                    idx += 1
                    continue
                if not staged:
                    staged = True
                    if self.collect_data:
                        self._restage_buffers(resident)
                idx += 1
                yield ep, sp
            ei += 1

    def __iter__(self):
        for ep, sp in self.plan_steps():
            yield self.execute_step(ep, sp)

    # -- one step -------------------------------------------------------------

    def gather_peers(self, sp) -> list | None:
        """Serve every node's planned peer fetches for one step, up front.

        Must run before any of the step's admission/eviction deltas are
        applied (the plan guarantees source residency only at step *start* —
        a source may evict the fetched sample in this very step, see
        :mod:`repro.data.peer`).  Returns per-node ``(ids, rows)`` pairs (or
        ``None`` entries), ready for :meth:`execute_step`'s assembly; samples
        the transport could not serve are simply absent and fall back to
        store reads downstream.
        """
        if self.peer_exchange is None or not self.collect_data:
            return None
        t0 = time.perf_counter()
        out = []
        for npn in sp.nodes:
            if npn.peer_fetches:
                ids, rows, _missing = self.peer_exchange.gather(npn.peer_fetches)
                out.append((ids, rows))
            else:
                out.append(None)
        self.report.served_by_source = {
            int(k): int(v)
            for k, v in self.peer_exchange.served_by_source.items()
        }
        stats = getattr(self.peer_exchange.transport, "stats", None)
        if callable(stats):
            self.report.transport_stats = stats()
        self.report.wall_time_s += time.perf_counter() - t0
        return out

    def execute_step(self, ep, sp, chunk_arrays=None, peer_arrays=None) -> StepBatch:
        """Account + assemble one planned step into a :class:`StepBatch`.

        ``chunk_arrays`` optionally supplies per-node pre-read chunk data (the
        async pipeline reads them concurrently ahead of time); when ``None``
        and ``collect_data`` is set, chunk reads are issued synchronously.
        ``peer_arrays`` optionally supplies the step's already-gathered peer
        rows (the async pipeline overlaps :meth:`gather_peers` with in-flight
        chunk reads); when ``None`` they are gathered here, before any delta
        is applied.  The plan's recorded admissions/evictions are replayed as
        deltas so the data buffer mirrors the planned simulation exactly.
        """
        chunks = [n.chunks for n in sp.nodes]
        self._account(
            chunks,
            [n.num_pfs_misses for n in sp.nodes],
            [n.num_real for n in sp.nodes],
            [n.num_hits for n in sp.nodes],
            per_node_remote=[n.num_peer for n in sp.nodes],
            per_node_remote_billable=[
                sum(1 for f in n.peer_fetches if f.source != n.node)
                for n in sp.nodes
            ],
        )
        if peer_arrays is None:
            peer_arrays = self.gather_peers(sp)
        data = [] if self.collect_data else None
        # Per-node state (occupancy, mirrors) is keyed by the plan's global
        # node id, not list position: a for_node() slice carries one plan
        # per step whose ``node`` is the rank, and must not alias rank 0's
        # buffer.  chunk_arrays/peer_arrays stay positional (parallel to
        # sp.nodes).
        for n, npn in enumerate(sp.nodes):
            r = npn.node
            self._occupancy[r] += npn.admissions.size - npn.evictions.size
            assert self._occupancy[r] <= self.buffer_size
            if not self.collect_data:
                continue
            delta = (npn.admissions, npn.evictions)
            extra = peer_arrays[n] if peer_arrays is not None else None
            if chunk_arrays is None:
                data.append(
                    self._fetch(r, npn.sample_ids, npn.chunks, delta, extra=extra)
                )
            else:
                t0 = time.perf_counter()
                data.append(
                    self._assemble(
                        r, npn.sample_ids, npn.chunks, chunk_arrays[n], delta,
                        extra=extra,
                    )
                )
                self.report.wall_time_s += time.perf_counter() - t0
        return StepBatch(
            ep.epoch_id,
            sp.step,
            [n.sample_ids for n in sp.nodes],
            data,
            [n.hit_mask for n in sp.nodes],
        )

    # -- accounting -----------------------------------------------------------

    def _account(
        self,
        per_node_chunks,
        per_node_miss,
        per_node_batch,
        per_node_hits,
        per_node_remote=None,
        per_node_remote_billable=None,
    ) -> None:
        """``per_node_remote_billable`` prices the remote fetches when it
        differs from the reported count — SOLAR's self-source peer fetches
        (sample bounced back to its own holder) are counted but cost no
        transfer (DESIGN.md §6)."""
        r = self.report
        r.pfs_counts.append([sum(c.span for c in cs) for cs in per_node_chunks])
        r.miss_counts.append(list(per_node_miss))
        r.batch_sizes.append(list(per_node_batch))
        r.remote_counts.append(
            list(per_node_remote) if per_node_remote else [0] * self.num_nodes
        )
        r.total_hits += int(sum(per_node_hits))
        r.total_samples += int(sum(per_node_batch))
        if per_node_remote_billable is None:
            per_node_remote_billable = per_node_remote
        node_times = []
        for n, cs in enumerate(per_node_chunks):
            t = self.cost.chunks_time(cs)
            if per_node_remote_billable:
                t += self.remote_time(per_node_remote_billable[n])
            node_times.append(t)
        r.modeled_time_s += max(node_times) if node_times else 0.0

    # -- batch materialization ------------------------------------------------

    def _fetch(
        self, node: int, ids, chunks, delta=None, extra=None
    ) -> np.ndarray | None:
        """Materialize one node's batch: buffer hits from RAM, misses via reads."""
        if not self.collect_data:
            return None
        t0 = time.perf_counter()
        arrays = self.store.read_ranges([(c.start, c.stop) for c in chunks])
        out = self._assemble(node, ids, chunks, arrays, delta, extra=extra)
        self.report.wall_time_s += time.perf_counter() - t0
        return out

    def _assemble(
        self, node: int, ids, chunks, chunk_arrays, delta=None, extra=None
    ) -> np.ndarray:
        """Gather one node's batch rows from pre-read chunks + the buffer mirror.

        Vectorized: misses come out of the concatenated chunk arrays via
        ``np.searchsorted``, hits out of the :class:`_DataMirror` arena, and
        anything uncovered (e.g. peer fetches with no transport, or hits on
        rows the mirror dropped across a ``fast_forward``) falls back to a
        coalesced scattered read.  ``extra`` is an optional ``(ids, rows)``
        pair of already-fetched samples (the planned peer tier) merged into
        the fetched pool, so peer rows serve both batch assembly and buffer
        admission without touching the store.
        """
        ids = np.asarray(ids, np.int64)
        shape, dtype = self.store.sample_shape, self.store.dtype
        if chunks:
            fetched_ids = np.concatenate(
                [np.arange(c.start, c.stop, dtype=np.int64) for c in chunks]
            )
            fetched_data = (
                chunk_arrays[0]
                if len(chunk_arrays) == 1
                else np.concatenate(chunk_arrays)
            )
        else:
            fetched_ids = np.empty(0, np.int64)
            fetched_data = np.empty((0,) + shape, dtype)
        if extra is not None and extra[0].size:
            fetched_ids = np.concatenate([fetched_ids, extra[0]])
            fetched_data = (
                np.concatenate([fetched_data, extra[1]])
                if fetched_data.size
                else extra[1]
            )
        if fetched_ids.size > 1 and not (np.diff(fetched_ids) > 0).all():
            order = np.argsort(fetched_ids, kind="stable")
            fetched_ids, fetched_data = fetched_ids[order], fetched_data[order]
        out = np.empty((ids.size,) + shape, dtype)
        need = np.ones(ids.size, bool)
        if fetched_ids.size and ids.size:
            pos = np.minimum(np.searchsorted(fetched_ids, ids), fetched_ids.size - 1)
            from_chunks = fetched_ids[pos] == ids
            out[from_chunks] = fetched_data[pos[from_chunks]]
            need &= ~from_chunks
        if need.any():
            mirror = self._mirror(node)
            slots = mirror.lookup(ids[need])
            found = slots >= 0
            if found.any():
                idx = np.flatnonzero(need)[found]
                out[idx] = mirror.rows(slots[found])
                need[idx] = False
        if need.any():  # remote fetch / uncovered: coalesced direct reads
            fallback = self.store.read_scattered(ids[need])
            out[need] = fallback
            # merge into the fetched pool so the delta replay below can admit
            # these rows (e.g. transport-less peer fetches the plan buffers)
            # without issuing a second read for the same samples.
            uids, first = np.unique(ids[need], return_index=True)
            fetched_ids = np.concatenate([fetched_ids, uids])
            fetched_data = (
                np.concatenate([fetched_data, fallback[first]])
                if fetched_data.size
                else fallback[first]
            )
            order = np.argsort(fetched_ids, kind="stable")
            fetched_ids, fetched_data = fetched_ids[order], fetched_data[order]
        self._sync_data_buffer(node, fetched_ids, fetched_data, delta)
        return out

    def _mirror(self, node: int) -> _DataMirror:
        if self._data_buf[node] is None:
            self._data_buf[node] = _DataMirror(
                self.buffer_size, self.store.sample_shape, self.store.dtype
            )
        return self._data_buf[node]

    def _sync_data_buffer(
        self, node: int, fetched_ids: np.ndarray, fetched_data: np.ndarray, delta
    ) -> None:
        """Replay the plan's ``(admissions, evictions)`` delta on the mirror.

        Admitted rows come from the fetched pool (chunks + peer rows); any
        admission the pool does not cover — defensive, plans normally cover
        them — is read back from the store so the mirror never holds wrong
        bytes.
        """
        admissions, evictions = delta
        mirror = self._mirror(node)
        mirror.evict(evictions)
        admissions = np.asarray(admissions, np.int64)
        if admissions.size:
            pos = np.minimum(
                np.searchsorted(fetched_ids, admissions),
                max(fetched_ids.size - 1, 0),
            )
            covered = (
                fetched_ids[pos] == admissions
                if fetched_ids.size
                else np.zeros(admissions.size, bool)
            )
            rows = np.empty(
                (admissions.size,) + self.store.sample_shape, self.store.dtype
            )
            rows[covered] = fetched_data[pos[covered]]
            if not covered.all():
                rows[~covered] = self.store.read_scattered(admissions[~covered])
            mirror.admit(admissions, rows)
