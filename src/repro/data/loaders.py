"""Data loaders: SOLAR and every baseline the paper compares against.

All loaders share one interface so the benchmarks and the trainer are
loader-agnostic:

  * :class:`NaiveLoader`   — PyTorch-DataLoader analog: fresh shuffle each
    epoch, contiguous node split, no buffer, per-sample PFS reads.
  * :class:`LRULoader`     — Naive + per-node LRU buffer (paper §5.3's
    "PyTorch DataLoader + LRU" ablation baseline).
  * :class:`NoPFSLoader`   — clairvoyant-*next-epoch* prefetch/buffer analog
    of Dryden et al. (2021): eviction by next-use distance, but the horizon is
    only the following epoch, and misses may be served from *remote* node
    buffers (inter-node fetch) before falling back to the PFS.
  * :class:`DeepIOLoader`  — Zhu et al. (2018) analog: partition-resident
    buffers, shuffle only *within* each node's resident set (sacrifices
    randomness — which is exactly why SOLAR rejects this design).
  * :class:`SolarLoader`   — executes the offline :class:`Schedule`: Belady
    buffer, locality remap, load-balanced misses, aggregated chunk reads.

Each loader yields :class:`StepBatch` objects and accumulates a
:class:`LoaderReport` with numPFS / modeled PFS time / wall time, which is
what the paper's figures plot.

Loaders are storage-agnostic: ``store`` is any
:class:`~repro.data.backends.base.StorageBackend` (flat binary, HDF5,
RAM-staged, sharded, ...) — every access goes through the protocol's
``read_ranges`` / ``read_scattered`` coalescing read paths.  Construct
loaders declaratively via :func:`repro.data.pipeline.build_pipeline`.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.buffer import BeladyBuffer, LRUBuffer
from repro.core.costmodel import PeerCostModel, PFSCostModel
from repro.core.plan import Schedule
from repro.core.scheduler import OfflineScheduler, SolarConfig, build_next_use_index
from repro.core.shuffle import (
    default_node_assignment,
    generate_epoch_permutations,
    split_global_batches,
)
from repro.data.backends.base import StorageBackend

__all__ = [
    "StepBatch",
    "LoaderReport",
    "NaiveLoader",
    "LRULoader",
    "NoPFSLoader",
    "DeepIOLoader",
    "SolarLoader",
    "LOADERS",
]


@dataclasses.dataclass
class StepBatch:
    epoch: int
    step: int
    #: per-node real sample ids.
    node_ids: list[np.ndarray]
    #: per-node sample arrays, [num_real, *sample_shape]; None when counting only.
    node_data: list[np.ndarray] | None
    #: per-node hit masks (True = served from buffer).
    hit_masks: list[np.ndarray]

    def to_global(self, capacity: int):
        """Pad each node to ``capacity`` rows and stack: SPMD-ready batch.

        Returns ``(data, weights)`` with shapes ``[N*capacity, ...]`` and
        ``[N*capacity]``; dummy rows have weight 0 so the masked loss makes
        gradients identical to the unpadded batch (DESIGN.md §3).
        """
        assert self.node_data is not None
        n = len(self.node_ids)
        shape = self.node_data[0].shape[1:]
        dtype = self.node_data[0].dtype
        data = np.zeros((n, capacity) + shape, dtype)
        weights = np.zeros((n, capacity), np.float32)
        for i, arr in enumerate(self.node_data):
            k = min(arr.shape[0], capacity)
            data[i, :k] = arr[:k]
            weights[i, :k] = 1.0
        return data.reshape((n * capacity,) + shape), weights.reshape(-1)


@dataclasses.dataclass
class LoaderReport:
    name: str
    num_nodes: int
    #: per-(step, node) PFS sample counts (misses incl. chunk waste).
    pfs_counts: list[list[int]] = dataclasses.field(default_factory=list)
    #: per-(step, node) PFS miss counts (wanted samples only; misses served
    #: from a remote buffer are in ``remote_counts`` instead).
    miss_counts: list[list[int]] = dataclasses.field(default_factory=list)
    #: per-(step, node) remote-buffer fetch counts (NoPFS online fetches /
    #: SOLAR planned peer fetches).
    remote_counts: list[list[int]] = dataclasses.field(default_factory=list)
    #: per-(step, node) batch sizes.
    batch_sizes: list[list[int]] = dataclasses.field(default_factory=list)
    modeled_time_s: float = 0.0
    wall_time_s: float = 0.0
    total_hits: int = 0
    total_samples: int = 0

    @property
    def total_pfs(self) -> int:
        return int(np.sum(self.pfs_counts)) if self.pfs_counts else 0

    @property
    def total_misses(self) -> int:
        return int(np.sum(self.miss_counts)) if self.miss_counts else 0

    @property
    def hit_rate(self) -> float:
        return self.total_hits / self.total_samples if self.total_samples else 0.0

    @property
    def total_remote(self) -> int:
        return int(np.sum(self.remote_counts)) if self.remote_counts else 0

    @property
    def max_step_pfs(self) -> np.ndarray:
        return np.asarray(self.pfs_counts).max(axis=1)

    def summary(self) -> dict:
        return {
            "loader": self.name,
            "numPFS": self.total_pfs,
            "misses": self.total_misses,
            "remote_fetches": self.total_remote,
            "hit_rate": round(self.hit_rate, 4),
            "modeled_time_s": round(self.modeled_time_s, 3),
            "wall_time_s": round(self.wall_time_s, 3),
        }


class _DataMirror:
    """Array-backed mirror of one node's buffer contents (id -> sample row).

    Lookups are vectorized (sorted id array + ``np.searchsorted``); admissions
    copy only the admitted rows into free slots of a preallocated arena and
    evictions only release slots — there is no per-step rebuild of the buffer.
    """

    def __init__(self, capacity: int, sample_shape: tuple[int, ...], dtype):
        self.capacity = max(int(capacity), 1)
        self._sample_shape = sample_shape
        self._dtype = dtype
        self._data: np.ndarray | None = None  # allocated on first admit
        self.ids = np.empty(0, np.int64)      # sorted
        self._slots = np.empty(0, np.int64)   # parallel to ids
        self._free = list(range(self.capacity - 1, -1, -1))

    def lookup(self, want: np.ndarray) -> np.ndarray:
        """Arena slot per wanted id, -1 where absent."""
        want = np.asarray(want, np.int64)
        if want.size == 0 or self.ids.size == 0:
            return np.full(want.size, -1, np.int64)
        pos = np.minimum(np.searchsorted(self.ids, want), self.ids.size - 1)
        return np.where(self.ids[pos] == want, self._slots[pos], -1)

    def rows(self, slots: np.ndarray) -> np.ndarray:
        assert self._data is not None
        return self._data[slots]

    def evict(self, ids) -> None:
        ids = np.asarray(ids, np.int64)
        if ids.size == 0 or self.ids.size == 0:
            return
        keep = ~np.isin(self.ids, ids, assume_unique=True)
        self._free.extend(int(s) for s in self._slots[~keep].tolist())
        self.ids = self.ids[keep]
        self._slots = self._slots[keep]

    def admit(self, ids, rows) -> None:
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return
        present = self.lookup(ids) >= 0
        if present.any():  # re-admission of a resident id is a no-op
            ids, rows = ids[~present], rows[~present]
            if ids.size == 0:
                return
        if self._data is None:
            self._data = np.empty(
                (self.capacity,) + self._sample_shape, self._dtype
            )
        slots = np.asarray([self._free.pop() for _ in range(ids.size)], np.int64)
        self._data[slots] = rows
        all_ids = np.concatenate([self.ids, ids])
        all_slots = np.concatenate([self._slots, slots])
        order = np.argsort(all_ids, kind="stable")
        self.ids = all_ids[order]
        self._slots = all_slots[order]


class _Base:
    name = "base"

    def __init__(
        self,
        store: StorageBackend,
        num_nodes: int,
        local_batch: int,
        num_epochs: int,
        buffer_size: int,
        seed: int = 0,
        cost_model: PFSCostModel | None = None,
        collect_data: bool = False,
    ):
        self.store = store
        self.num_nodes = num_nodes
        self.local_batch = local_batch
        self.num_epochs = num_epochs
        self.buffer_size = buffer_size
        self.seed = seed
        self.cost = cost_model or PFSCostModel(sample_bytes=store.sample_bytes)
        self.collect_data = collect_data
        self.report = LoaderReport(name=self.name, num_nodes=num_nodes)
        self.perms = generate_epoch_permutations(
            store.num_samples, num_epochs, seed
        )
        # per-node data buffers (actual arrays) when materializing batches.
        self._data_buf: list[_DataMirror | None] = [None] * num_nodes

    # subclasses implement __iter__ yielding StepBatch.

    def _account(
        self,
        per_node_chunks,
        per_node_miss,
        per_node_batch,
        per_node_hits,
        per_node_remote=None,
        per_node_remote_billable=None,
    ) -> None:
        """``per_node_remote_billable`` prices the remote fetches when it
        differs from the reported count — SOLAR's self-source peer fetches
        (sample bounced back to its own holder) are counted but cost no
        transfer (DESIGN.md §6)."""
        r = self.report
        r.pfs_counts.append([sum(c.span for c in cs) for cs in per_node_chunks])
        r.miss_counts.append(list(per_node_miss))
        r.batch_sizes.append(list(per_node_batch))
        r.remote_counts.append(
            list(per_node_remote) if per_node_remote else [0] * self.num_nodes
        )
        r.total_hits += int(sum(per_node_hits))
        r.total_samples += int(sum(per_node_batch))
        if per_node_remote_billable is None:
            per_node_remote_billable = per_node_remote
        node_times = []
        for n, cs in enumerate(per_node_chunks):
            t = self.cost.chunks_time(cs)
            if per_node_remote_billable:
                t += self.remote_time(per_node_remote_billable[n])
            node_times.append(t)
        r.modeled_time_s += max(node_times) if node_times else 0.0

    def remote_time(self, k: int, interconnect_bps: float = 1.0e10,
                    latency_s: float = 5e-5) -> float:
        return k * (latency_s + self.store.sample_bytes / interconnect_bps)

    def _fetch(
        self, node: int, ids, chunks, delta=None, extra=None
    ) -> np.ndarray | None:
        """Materialize one node's batch: buffer hits from RAM, misses via reads."""
        if not self.collect_data:
            return None
        t0 = time.perf_counter()
        arrays = self.store.read_ranges([(c.start, c.stop) for c in chunks])
        out = self._assemble(node, ids, chunks, arrays, delta, extra=extra)
        self.report.wall_time_s += time.perf_counter() - t0
        return out

    def _assemble(
        self, node: int, ids, chunks, chunk_arrays, delta=None, extra=None
    ) -> np.ndarray:
        """Gather one node's batch rows from pre-read chunks + the buffer mirror.

        Vectorized: misses come out of the concatenated chunk arrays via
        ``np.searchsorted``, hits out of the :class:`_DataMirror` arena, and
        anything uncovered (e.g. NoPFS remote-buffer fetches) falls back to a
        coalesced scattered read.  ``extra`` is an optional ``(ids, rows)``
        pair of already-fetched samples (the planned peer tier) merged into
        the fetched pool, so peer rows serve both batch assembly and buffer
        admission without touching the store.
        """
        ids = np.asarray(ids, np.int64)
        shape, dtype = self.store.sample_shape, self.store.dtype
        if chunks:
            fetched_ids = np.concatenate(
                [np.arange(c.start, c.stop, dtype=np.int64) for c in chunks]
            )
            fetched_data = (
                chunk_arrays[0]
                if len(chunk_arrays) == 1
                else np.concatenate(chunk_arrays)
            )
        else:
            fetched_ids = np.empty(0, np.int64)
            fetched_data = np.empty((0,) + shape, dtype)
        if extra is not None and extra[0].size:
            fetched_ids = np.concatenate([fetched_ids, extra[0]])
            fetched_data = (
                np.concatenate([fetched_data, extra[1]])
                if fetched_data.size
                else extra[1]
            )
        if fetched_ids.size > 1 and not (np.diff(fetched_ids) > 0).all():
            order = np.argsort(fetched_ids, kind="stable")
            fetched_ids, fetched_data = fetched_ids[order], fetched_data[order]
        out = np.empty((ids.size,) + shape, dtype)
        need = np.ones(ids.size, bool)
        if fetched_ids.size and ids.size:
            pos = np.minimum(np.searchsorted(fetched_ids, ids), fetched_ids.size - 1)
            from_chunks = fetched_ids[pos] == ids
            out[from_chunks] = fetched_data[pos[from_chunks]]
            need &= ~from_chunks
        if need.any():
            mirror = self._mirror(node)
            slots = mirror.lookup(ids[need])
            found = slots >= 0
            if found.any():
                idx = np.flatnonzero(need)[found]
                out[idx] = mirror.rows(slots[found])
                need[idx] = False
        if need.any():  # remote fetch / uncovered: coalesced direct reads
            out[need] = self.store.read_scattered(ids[need])
        self._sync_data_buffer(node, fetched_ids, fetched_data, delta)
        return out

    def _mirror(self, node: int) -> _DataMirror:
        if self._data_buf[node] is None:
            self._data_buf[node] = _DataMirror(
                self.buffer_size, self.store.sample_shape, self.store.dtype
            )
        return self._data_buf[node]

    def _sync_data_buffer(
        self, node: int, fetched_ids: np.ndarray, fetched_data: np.ndarray, delta=None
    ) -> None:
        """Mirror the logical buffer: keep rows only for resident ids.

        When ``delta`` is ``(admissions, evictions)`` (the SOLAR plan records
        them), the mirror is updated from the deltas alone; otherwise the
        resident set is re-derived from :meth:`_resident_ids`.
        """
        if delta is not None:
            admissions, evictions = delta
            mirror = self._mirror(node)
            mirror.evict(evictions)
            admissions = np.asarray(admissions, np.int64)
            if admissions.size:
                pos = np.minimum(
                    np.searchsorted(fetched_ids, admissions),
                    max(fetched_ids.size - 1, 0),
                )
                covered = (
                    fetched_ids[pos] == admissions
                    if fetched_ids.size
                    else np.zeros(admissions.size, bool)
                )
                rows = np.empty(
                    (admissions.size,) + self.store.sample_shape, self.store.dtype
                )
                rows[covered] = fetched_data[pos[covered]]
                if not covered.all():  # defensive: plan admissions ⊆ chunks
                    rows[~covered] = self.store.read_scattered(admissions[~covered])
                mirror.admit(admissions, rows)
            return
        resident = self._resident_ids(node)
        if not resident and self._data_buf[node] is None:
            return
        mirror = self._mirror(node)
        res = np.fromiter(resident, np.int64, count=len(resident))
        res.sort()
        if mirror.ids.size:
            gone = (
                mirror.ids[~np.isin(mirror.ids, res, assume_unique=True)]
                if res.size
                else mirror.ids
            )
            mirror.evict(gone)
        if fetched_ids.size and res.size:
            keep = np.isin(fetched_ids, res, assume_unique=True)
            if keep.any():
                mirror.admit(fetched_ids[keep], fetched_data[keep])

    def _resident_ids(self, node: int) -> set:
        return set()


def _singleton_chunks(ids):
    from repro.core.plan import ChunkRead

    return tuple(ChunkRead(int(s), int(s) + 1, 1) for s in sorted(ids))


class NaiveLoader(_Base):
    """Fresh shuffle, contiguous split, no buffer, per-sample reads."""

    name = "naive"

    def __iter__(self):
        for e in range(self.num_epochs):
            batches = split_global_batches(
                self.perms[e], self.num_nodes * self.local_batch
            )
            for k in range(batches.shape[0]):
                split = default_node_assignment(batches[k], self.num_nodes)
                chunks = [_singleton_chunks(ids) for ids in split]
                self._account(
                    chunks,
                    [len(s) for s in split],
                    [len(s) for s in split],
                    [0] * self.num_nodes,
                )
                data = [self._fetch(n, split[n], chunks[n]) for n in range(self.num_nodes)]
                yield StepBatch(
                    e,
                    k,
                    list(split),
                    data if self.collect_data else None,
                    [np.zeros(len(s), bool) for s in split],
                )


class LRULoader(_Base):
    """Naive + per-node LRU buffer (paper §5.3 baseline)."""

    name = "lru"

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.bufs = [LRUBuffer(self.buffer_size) for _ in range(self.num_nodes)]

    def _resident_ids(self, node):
        return self.bufs[node].resident

    def __iter__(self):
        for e in range(self.num_epochs):
            batches = split_global_batches(
                self.perms[e], self.num_nodes * self.local_batch
            )
            for k in range(batches.shape[0]):
                split = default_node_assignment(batches[k], self.num_nodes)
                chunks, hits, masks = [], [], []
                for n, ids in enumerate(split):
                    m = np.asarray([int(s) in self.bufs[n] for s in ids])
                    miss = [int(s) for s in ids[~m]]
                    chunks.append(_singleton_chunks(miss))
                    hits.append(int(m.sum()))
                    masks.append(m)
                    for s in ids:
                        self.bufs[n].admit(int(s))
                self._account(
                    chunks,
                    [len(ids) - h for ids, h in zip(split, hits)],
                    [len(s) for s in split],
                    hits,
                )
                data = [self._fetch(n, split[n], chunks[n]) for n in range(self.num_nodes)]
                yield StepBatch(e, k, list(split), data if self.collect_data else None, masks)


class NoPFSLoader(_Base):
    """Clairvoyant-next-epoch buffering + remote-buffer fetches (NoPFS analog).

    Eviction uses exact next-use distances but only *within a one-epoch
    horizon* (NoPFS predicts the next epoch's distribution); a miss checks the
    other nodes' buffers (hierarchical storage) before touching the PFS —
    faster than PFS, slower than local, and it is inter-node traffic SOLAR
    avoids by construction.
    """

    name = "nopfs"

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.bufs = [BeladyBuffer(self.buffer_size) for _ in range(self.num_nodes)]

    def _resident_ids(self, node):
        return self.bufs[node].resident

    def __iter__(self):
        d = self.perms.shape[1]
        gb = self.num_nodes * self.local_batch
        steps = d // gb
        span = steps * gb
        horizon = 2 * span  # current + next epoch
        for e in range(self.num_epochs):
            # Access string visible to NoPFS: this epoch + the next one.
            cur = self.perms[e, :span]
            nxt_ep = self.perms[e + 1, :span] if e + 1 < self.num_epochs else None
            window = np.concatenate([cur, nxt_ep]) if nxt_ep is not None else cur
            next_use = build_next_use_index(window)
            batches = cur.reshape(steps, gb)
            for k in range(steps):
                split = default_node_assignment(batches[k], self.num_nodes)
                base = k * gb
                chunks, missc, hits, remote, masks = [], [], [], [], []
                for n, ids in enumerate(split):
                    m = np.zeros(len(ids), bool)
                    miss_pfs, n_remote = [], 0
                    for i, s in enumerate(ids.tolist()):
                        pos = base + n * self.local_batch + i
                        nu = int(next_use[pos]) if pos < window.size else horizon
                        if s in self.bufs[n]:
                            m[i] = True
                            self.bufs[n].update_next_use(s, nu)
                        elif any(s in self.bufs[r] for r in range(self.num_nodes) if r != n):
                            n_remote += 1
                            self.bufs[n].admit(s, nu)
                        else:
                            miss_pfs.append(s)
                            self.bufs[n].admit(s, nu)
                    chunks.append(_singleton_chunks(miss_pfs))
                    missc.append(len(miss_pfs))
                    hits.append(int(m.sum()))
                    remote.append(n_remote)
                    masks.append(m)
                self._account(chunks, missc, [len(s) for s in split], hits, remote)
                data = [self._fetch(n, split[n], chunks[n]) for n in range(self.num_nodes)]
                yield StepBatch(e, k, list(split), data if self.collect_data else None, masks)


class DeepIOLoader(_Base):
    """Partition-resident buffers + node-local shuffle (DeepIO analog).

    Maximum reuse, but the randomization is node-local only — the design SOLAR
    rejects because it degrades surrogate accuracy (paper §4.2.2).
    """

    name = "deepio"

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        d = self.store.num_samples
        per = min(self.buffer_size, (d + self.num_nodes - 1) // self.num_nodes)
        self._partition = [
            np.arange(n * per, min((n + 1) * per, d)) for n in range(self.num_nodes)
        ]
        leftover_start = min(per * self.num_nodes, d)
        self._leftover = np.arange(leftover_start, d)
        self._primed = [False] * self.num_nodes

    def _resident_ids(self, node):
        return set(self._partition[node].tolist())

    def __iter__(self):
        from repro.core.chunking import plan_chunks
        from repro.core.plan import ChunkRead

        rng = np.random.Generator(np.random.PCG64(self.seed + 7))
        steps = self.store.num_samples // (self.num_nodes * self.local_batch)
        for e in range(self.num_epochs):
            local_orders = [rng.permutation(p) for p in self._partition]
            leftover = rng.permutation(self._leftover)
            lo_steps = (
                np.array_split(leftover, steps)
                if leftover.size
                else [np.empty(0, np.int64)] * steps
            )
            for k in range(steps):
                ids_n, chunks, missc, hits, masks = [], [], [], [], []
                lo_split = np.array_split(lo_steps[k], self.num_nodes)
                for n in range(self.num_nodes):
                    want = self.local_batch - lo_split[n].size
                    res = np.take(
                        local_orders[n],
                        np.arange(k * want, (k + 1) * want),
                        mode="wrap",
                    ) if local_orders[n].size else np.empty(0, np.int64)
                    ids = np.concatenate([res, lo_split[n]])
                    m = np.zeros(ids.size, bool)
                    if self._primed[n]:
                        # Residents are hits; only the leftover tail hits PFS.
                        m[: res.size] = True
                        cs = plan_chunks(lo_split[n], max_chunk=16)
                        miss = int(lo_split[n].size)
                    else:
                        # Stage-in: one ranged read of the whole partition
                        # (DeepIO's whole point) + this step's leftovers.
                        part = self._partition[n]
                        cs = ()
                        if part.size:
                            cs = (ChunkRead(int(part[0]), int(part[-1]) + 1, part.size),)
                        cs = cs + plan_chunks(lo_split[n], max_chunk=16)
                        miss = int(ids.size)
                        self._primed[n] = True
                    chunks.append(cs)
                    ids_n.append(ids)
                    missc.append(miss)
                    hits.append(int(m.sum()))
                    masks.append(m)
                self._account(chunks, missc, [i.size for i in ids_n], hits)
                data = [
                    self._fetch(n, ids_n[n], chunks[n]) for n in range(self.num_nodes)
                ]
                yield StepBatch(e, k, ids_n, data if self.collect_data else None, masks)


class SolarLoader(_Base):
    """Executes the SOLAR offline schedule against the store.

    With ``enable_peer`` set on the :class:`SolarConfig`, the schedule's
    planned peer fetches (DESIGN.md §6) are served through a
    :class:`~repro.data.peer.PeerExchange` — in-process shared-view transport
    by default, or any :class:`~repro.data.peer.PeerTransport` passed as
    ``peer_transport`` — instead of touching the PFS.
    """

    name = "solar"

    def __init__(
        self,
        *args,
        solar_config: SolarConfig | None = None,
        peer_transport=None,
        **kwargs,
    ):
        super().__init__(*args, **kwargs)
        cfg = solar_config or SolarConfig(
            num_nodes=self.num_nodes,
            local_batch=self.local_batch,
            buffer_size=self.buffer_size,
            seed=self.seed,
        )
        if cfg.enable_peer and cfg.peer_cost is None:
            # Price the peer-vs-PFS decision with this store's real sample
            # size and the loader's PFS model.
            cfg = dataclasses.replace(
                cfg,
                peer_cost=PeerCostModel(
                    sample_bytes=self.store.sample_bytes, pfs=self.cost
                ),
            )
        self.solar_config = cfg
        self.scheduler = OfflineScheduler(self.solar_config)
        t0 = time.perf_counter()
        self.schedule: Schedule = self.scheduler.build(
            self.store.num_samples, self.num_epochs, perms=self.perms
        )
        self.schedule_build_s = time.perf_counter() - t0
        # Buffer occupancy per node, maintained from the plan's recorded
        # admission/eviction deltas — no per-step resident-set rebuild.
        self._occupancy = [0] * self.num_nodes
        self.peer_exchange = None
        if cfg.enable_peer:
            from repro.data.peer import PeerExchange, SharedViewTransport

            self.peer_exchange = PeerExchange(
                peer_transport or SharedViewTransport(self._mirror),
                self.store.sample_shape,
                self.store.dtype,
            )

    @property
    def capacity(self) -> int:
        return self.schedule.capacity

    def remote_time(self, k: int, **kwargs) -> float:
        cfg = self.solar_config
        if cfg.peer_cost is not None:
            return cfg.peer_cost.fetch_time(k)
        return super().remote_time(k, **kwargs)

    def reset_execution(self) -> None:
        """Forget buffer state so the schedule can be replayed from step 0."""
        self._occupancy = [0] * self.num_nodes
        self._data_buf = [None] * self.num_nodes

    def plan_steps(self):
        """Walk the schedule in execution order, yielding (EpochPlan, StepPlan).

        This is the surface the :class:`repro.data.prefetch.PrefetchExecutor`
        pipelines over: every future ChunkRead is visible here.  Each walk
        replays the Belady simulation from an empty buffer.
        """
        self.reset_execution()
        for ep in self.schedule.epochs:
            for sp in ep.steps:
                yield ep, sp

    def gather_peers(self, sp) -> list | None:
        """Serve every node's planned peer fetches for one step, up front.

        Must run before any of the step's admission/eviction deltas are
        applied (the plan guarantees source residency only at step *start* —
        a source may evict the fetched sample in this very step, see
        :mod:`repro.data.peer`).  Returns per-node ``(ids, rows)`` pairs (or
        ``None`` entries), ready for :meth:`execute_step`'s assembly; samples
        the transport could not serve are simply absent and fall back to
        store reads downstream.
        """
        if self.peer_exchange is None or not self.collect_data:
            return None
        t0 = time.perf_counter()
        out = []
        for npn in sp.nodes:
            if npn.peer_fetches:
                ids, rows, _missing = self.peer_exchange.gather(npn.peer_fetches)
                out.append((ids, rows))
            else:
                out.append(None)
        self.report.wall_time_s += time.perf_counter() - t0
        return out

    def execute_step(self, ep, sp, chunk_arrays=None, peer_arrays=None) -> StepBatch:
        """Account + assemble one planned step into a :class:`StepBatch`.

        ``chunk_arrays`` optionally supplies per-node pre-read chunk data (the
        async pipeline reads them concurrently ahead of time); when ``None``
        and ``collect_data`` is set, chunk reads are issued synchronously.
        ``peer_arrays`` optionally supplies the step's already-gathered peer
        rows (the async pipeline overlaps :meth:`gather_peers` with in-flight
        chunk reads); when ``None`` they are gathered here, before any delta
        is applied.  The plan's recorded admissions/evictions are replayed as
        deltas so the data buffer mirrors the Belady simulation exactly.
        """
        chunks = [n.chunks for n in sp.nodes]
        self._account(
            chunks,
            [n.num_pfs_misses for n in sp.nodes],
            [n.num_real for n in sp.nodes],
            [n.num_hits for n in sp.nodes],
            per_node_remote=[n.num_peer for n in sp.nodes],
            per_node_remote_billable=[
                sum(1 for f in n.peer_fetches if f.source != n.node)
                for n in sp.nodes
            ],
        )
        if peer_arrays is None:
            peer_arrays = self.gather_peers(sp)
        data = [] if self.collect_data else None
        for n, npn in enumerate(sp.nodes):
            self._occupancy[n] += npn.admissions.size - npn.evictions.size
            assert self._occupancy[n] <= self.buffer_size
            if not self.collect_data:
                continue
            delta = (npn.admissions, npn.evictions)
            extra = peer_arrays[n] if peer_arrays is not None else None
            if chunk_arrays is None:
                data.append(
                    self._fetch(n, npn.sample_ids, npn.chunks, delta, extra=extra)
                )
            else:
                t0 = time.perf_counter()
                data.append(
                    self._assemble(
                        n, npn.sample_ids, npn.chunks, chunk_arrays[n], delta,
                        extra=extra,
                    )
                )
                self.report.wall_time_s += time.perf_counter() - t0
        return StepBatch(
            ep.epoch_id,
            sp.step,
            [n.sample_ids for n in sp.nodes],
            data,
            [n.hit_mask for n in sp.nodes],
        )

    def __iter__(self):
        for ep, sp in self.plan_steps():
            yield self.execute_step(ep, sp)


#: loader-kind registry: the names :class:`repro.data.pipeline.LoaderSpec`
#: resolves its ``loader`` field through.
LOADERS = {
    c.name: c for c in (NaiveLoader, LRULoader, NoPFSLoader, DeepIOLoader, SolarLoader)
}
